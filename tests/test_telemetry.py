"""The repro.telemetry subsystem: counter correctness, metered energy vs
the analytical model, the 29× CMOS comparison, the lifetime projection,
and the conductance-domain ``analog_state`` backend."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analog.costmodel import M2RUCostModel
from repro.analog.crossbar import (CrossbarSpec, pair_weights, program_pair,
                                   update_pair)
from repro.backends import DeviceSpec, get_backend
from repro.core.continual import ReplaySpec, TrainerSpec, run_continual
from repro.core.miru import MiRUConfig
from repro.data.synthetic import make_permuted_tasks
from repro.telemetry import (MeteredEnergy, Telemetry, cmos_comparison,
                             project_lifetime, telemetry_report)

CFG = MiRUConfig(n_x=28, n_h=100, n_y=10)     # the paper shape


def _zero_noise_spec(track=False) -> DeviceSpec:
    return DeviceSpec(
        input_bits=8, adc_bits=8, adc_range=4.0, gain_sigma=0.02,
        weight_clip=1.5,
        crossbar=CrossbarSpec(write_sigma=0.0, read_sigma=0.0, w_clip=1.5,
                              prog_sigma=0.0, drift_rate=0.0),
        track_endurance=track)


@pytest.fixture(scope="module")
def tasks():
    return make_permuted_tasks(0, n_tasks=2, n_train=96, n_test=32)


@pytest.fixture(scope="module")
def metered_analog(tasks):
    """One shared telemetry run on the noisy analog_state backend."""
    backend = get_backend("analog_state",
                          spec_overrides=dict(track_endurance=True))
    backend.telemetry.enable()
    res = run_continual(CFG, TrainerSpec(algo="dfa", epochs_per_task=1),
                        tasks, replay=ReplaySpec(capacity=64),
                        device=backend)
    return backend, res


@pytest.fixture(scope="module")
def metered_cmos(tasks):
    backend = get_backend("cmos")
    backend.telemetry.enable()
    res = run_continual(CFG, TrainerSpec(algo="dfa", epochs_per_task=1),
                        tasks, replay=ReplaySpec(capacity=64),
                        device=backend)
    return backend, res


# ---------------------------------------------------------------------------
# Counter correctness — hand-computable 2×3 crossbar step
# ---------------------------------------------------------------------------

def test_counters_hand_computed_2x3_step():
    """One eager VMM + readout on a 2-in, 3-out crossbar: every counter is
    checkable by hand."""
    backend = get_backend(
        "wbs", spec=DeviceSpec(input_bits=4, adc_bits=6, adc_range=4.0,
                               weight_clip=1.0))
    backend.telemetry.enable()
    drive = jnp.array([[0.5, -0.25]])                   # 1 row, n_in = 2
    w = jnp.ones((2, 3)) * 0.1
    y = backend.device_vmm(drive, w, tag="w_h")
    backend.device_readout(y)                           # 1×3 ADC readout
    c = backend.telemetry.snapshot()
    assert c["vmm_rows/w_h"] == 1
    assert c["macs/w_h"] == 1 * 2 * 3
    assert c["bit_pulses/w_h"] == 1 * 2 * 4             # n_in × input_bits
    assert c["wbs_phases/w_h"] == 1 * 4                 # one phase per bit
    assert c["adc_conversions/hidden"] == 1 * 3         # one per channel


def test_counters_batch_rows_scale():
    backend = get_backend("wbs")
    backend.telemetry.enable()
    drive = jnp.zeros((5, 7, 2))                        # 35 rows
    w = jnp.zeros((2, 3))
    backend.device_vmm(drive, w, tag="x")
    c = backend.telemetry.snapshot()
    assert c["vmm_rows/x"] == 35
    assert c["macs/x"] == 35 * 2 * 3


def test_telemetry_disabled_by_default_and_free():
    backend = get_backend("wbs")
    assert not backend.telemetry.enabled
    backend.device_vmm(jnp.zeros((1, 2)), jnp.zeros((2, 3)))
    assert backend.telemetry.snapshot() == {}


def test_jit_scan_counts_per_execution():
    """Pending deltas + scaled scope + emit must count each compiled
    execution, including every scan iteration."""
    tele = Telemetry(enabled=True)

    @jax.jit
    def f(x):
        def body(c, _):
            tele.record({"inner": 2}, anchor=c)
            return c + 1.0, c
        with tele.scaled(5):
            c, _ = jax.lax.scan(body, x, None, length=5)
        tele.emit_pending()
        return c

    f(0.0)
    assert tele.snapshot()["inner"] == 10
    f(0.0)
    f(0.0)
    assert tele.snapshot()["inner"] == 30


# ---------------------------------------------------------------------------
# Metered energy vs the analytical model (28×100×10)
# ---------------------------------------------------------------------------

def test_metered_power_within_5pct_of_analytical(metered_analog):
    backend, _ = metered_analog
    m = M2RUCostModel()
    rep = MeteredEnergy(m).analog_report(backend.telemetry.snapshot())
    assert rep.power_w * 1e3 == pytest.approx(48.62, rel=0.05)
    assert rep.power_w == pytest.approx(m.power_w(), rel=0.05)
    # Derived throughput/latency agree with the model too.
    assert rep.gops == pytest.approx(m.gops(), rel=0.05)
    assert rep.time_s / rep.sample_steps == pytest.approx(
        m.step_latency_s(), rel=0.05)


def test_metered_efficiency_near_paper(metered_analog):
    backend, _ = metered_analog
    rep = MeteredEnergy().analog_report(backend.telemetry.snapshot())
    assert rep.gops_per_w == pytest.approx(312, rel=0.05)
    assert rep.pj_per_op == pytest.approx(3.21, rel=0.05)


def test_cmos_ratio_29x(metered_analog, metered_cmos):
    cmp = cmos_comparison(metered_analog[0].telemetry,
                          metered_cmos[0].telemetry)
    assert cmp["efficiency_gain"] == pytest.approx(29.0, rel=0.05)


def test_lifetime_projection_near_12_2_years(metered_analog):
    _, res = metered_analog
    proj = project_lifetime(res["endurance"])
    # ζ = 0.57 K-WTA selection → ~12.2 years (paper, Fig. 5b).
    assert proj.writes_per_device_update == pytest.approx(0.57, abs=0.03)
    assert proj.years_mean == pytest.approx(12.2, rel=0.15)
    # Dense writes (rate 1) would give the paper's 6.9-year figure.
    assert proj.years_hot_tail == pytest.approx(6.9, rel=0.15)


def test_full_report_assembles(metered_analog):
    backend, res = metered_analog
    rep = telemetry_report(backend.telemetry,
                           tracker=res.get("endurance"))
    assert rep["metered"]["power_mw"] == pytest.approx(
        rep["analytical"]["power_mw"], rel=0.05)
    assert "lifetime" in rep
    from repro.telemetry import format_report
    assert "GOPS/W" in format_report(rep)


# ---------------------------------------------------------------------------
# analog_state ≡ analog in the ideal-device limit
# ---------------------------------------------------------------------------

def test_analog_state_bit_identical_to_analog_at_zero_noise(tasks):
    runs = {}
    for name in ("analog", "analog_state"):
        backend = get_backend(name, spec=_zero_noise_spec(track=True))
        runs[name] = run_continual(
            CFG, TrainerSpec(algo="dfa", epochs_per_task=1), tasks,
            replay=ReplaySpec(capacity=64), device=backend)
    a, s = runs["analog"], runs["analog_state"]
    np.testing.assert_array_equal(a["R"], s["R"])
    for k in a["params"]:
        np.testing.assert_array_equal(np.asarray(a["params"][k]),
                                      np.asarray(s["params"][k]))
    # Same write maps → same lifetime projection.
    assert a["endurance"].mean_writes() == s["endurance"].mean_writes()


def test_analog_state_carries_conductance_state(metered_analog):
    _, res = metered_analog
    state = res["device_state"]
    assert set(state) == {"w_h", "u_h", "w_o"}
    for pair in state.values():
        g = np.concatenate([np.asarray(pair["g_pos"]).ravel(),
                            np.asarray(pair["g_neg"]).ravel()])
        spec = CrossbarSpec()
        assert (g >= spec.g_off - 1e-12).all()
        assert (g <= spec.g_on + 1e-12).all()


def test_pair_program_roundtrip_ideal():
    spec = CrossbarSpec(write_sigma=0.0, prog_sigma=0.0, w_clip=1.5)
    w = jnp.array([[0.7, -1.2, 0.0]])
    pair = program_pair(None, w, spec)
    np.testing.assert_allclose(np.asarray(pair_weights(pair, spec)),
                               np.asarray(w), rtol=1e-6, atol=1e-9)


def test_pair_update_saturates_at_window():
    """One-sided potentiation saturates: conductance-domain behavior the
    logical model cannot express."""
    spec = CrossbarSpec(write_sigma=0.0, prog_sigma=0.0, w_clip=1.0)
    pair = program_pair(None, jnp.array([0.95]), spec)
    for i in range(10):
        pair = update_pair(jax.random.PRNGKey(i), pair,
                           jnp.array([0.5]), spec)
    w = float(pair_weights(pair, spec)[0])
    assert w == pytest.approx(1.0, abs=1e-6)            # pinned at G_on


def test_drift_relaxes_weights_toward_zero():
    spec = CrossbarSpec(write_sigma=0.0, prog_sigma=0.0, drift_rate=0.1,
                        w_clip=1.0)
    backend = get_backend(
        "analog_state",
        spec=DeviceSpec(input_bits=8, adc_bits=8, weight_clip=1.0,
                        crossbar=spec))
    params = {"w_h": jnp.array([[0.8, -0.8]])}
    state = backend.init_device_state(params, jax.random.PRNGKey(0))
    zeros = {"w_h": jnp.zeros_like(params["w_h"])}
    p, _, state = backend.device_apply_update(
        params, zeros, jax.random.PRNGKey(1), state=state)
    np.testing.assert_allclose(np.asarray(p["w_h"]),
                               np.asarray(params["w_h"]) * 0.9, rtol=1e-5)


# ---------------------------------------------------------------------------
# Registry / serving integration
# ---------------------------------------------------------------------------

def test_new_backends_registered():
    from repro.backends import available_backends
    assert {"analog_state", "cmos"} <= set(available_backends())


def test_cmos_backend_is_exact_fixed_point():
    backend = get_backend("cmos")
    x = jax.random.uniform(jax.random.PRNGKey(0), (4, 8),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 3)) * 0.3
    y = backend.vmm(x, w)
    assert float(jnp.abs(y - x @ w).max()) < 0.05       # 8-bit quant only
    np.testing.assert_array_equal(np.asarray(backend.vmm(x, w)),
                                  np.asarray(y))        # deterministic


def test_serve_engine_validates_device_through_registry():
    from repro.configs import get_config
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_config("qwen2-0.5b")
    with pytest.raises(ValueError, match="unknown device backend"):
        ServeEngine(cfg, ServeConfig(batch_slots=1, max_len=8,
                                     device="not-a-backend"), params=None)
