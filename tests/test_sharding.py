"""Sharding rules + HLO analyzer + serving engine + continual claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        opt_state_specs, param_specs)
from repro.models import lm

MESH = AbstractMesh((("data", 16), ("model", 16)))


def _spec_of(tree, *path):
    node = tree
    for k in path:
        node = node[k]
    return node


def test_dense_param_specs():
    cfg = get_config("qwen3-4b")
    shapes = lm.param_shapes(cfg)
    specs = param_specs(cfg, shapes, MESH)
    # embed (V, D): vocab over model.
    assert specs["embed"] == P("model", None)
    # attention projections: (L, D, H·hd) fsdp×tp; wo flipped.
    layer = specs["layers"]["mixer"]
    assert layer["wq"] == P(None, "data", "model")
    assert layer["wo"] == P(None, "model", "data")
    # norms replicated.
    assert specs["layers"]["norm1"] == P(None, None)
    assert specs["final_norm"] == P(None)


def test_moe_expert_specs_ep_vs_replicate_fallback():
    ds = get_config("deepseek-v3-671b")       # 256 experts | 16 → EP
    specs = param_specs(ds, lm.param_shapes(ds), MESH)
    moe = specs["layers"]["ffn"]
    assert moe["w_gate"] == P(None, "model", "data", None)
    assert moe["w_down"] == P(None, "model", None, "data")

    # granite: 40 experts ∤ 16. Global dispatch (baseline) → TP over F;
    # EP-local dispatch (replicate_small_banks) → tiny 63 MB banks
    # replicate per device so MoE dispatch is fully local.
    gr = get_config("granite-moe-3b-a800m")
    shapes = lm.param_shapes(gr)
    moe = param_specs(gr, shapes, MESH)["layers"]["ffn"]
    assert moe["w_gate"] == P(None, None, "data", "model")
    moe = param_specs(gr, shapes, MESH,
                      replicate_small_banks=True)["layers"]["ffn"]
    assert moe["w_gate"] == P(None, None, None, None)


def test_nondivisible_dims_replicate():
    cfg = get_config("qwen2-0.5b")            # heads 14·64=896 ∤ ... D ✓
    shapes = lm.param_shapes(cfg)
    specs = param_specs(cfg, shapes, MESH)
    # vocab 151936 = 16·9496 divisible; kv proj out 128 divisible;
    # but seamless vocab is not:
    sm = get_config("seamless-m4t-medium")
    sspecs = param_specs(sm, lm.param_shapes(sm), MESH)
    assert sspecs["embed"] == P(None, None)   # 256206 % 16 != 0 → repl
    assert specs["embed"] == P("model", None)


def test_batch_and_cache_specs():
    cfg = get_config("yi-34b")
    from repro.configs.shapes import input_specs
    b = batch_specs(input_specs(cfg, "train_4k"), MESH, multi_pod=False)
    assert b["tokens"] == P("data", None)
    d = input_specs(cfg, "decode_32k")
    c = cache_specs(d["caches"], MESH, multi_pod=False)
    leaf_spec = jax.tree.leaves(
        c, is_leaf=lambda x: isinstance(x, P))[0]
    # batch 128 = 16·8: sharded over both axes where divisible.
    assert leaf_spec[1] is not None


def test_cache_specs_batch1_uses_model_axis():
    cfg = get_config("jamba-1.5-large-398b")
    from repro.configs.shapes import input_specs
    d = input_specs(cfg, "long_500k")
    c = cache_specs(d["caches"], MESH, multi_pod=False)
    flat = jax.tree.leaves(c, is_leaf=lambda x: isinstance(x, P))
    # batch 1: at least some caches still shard (TP on kv/head dims).
    assert any(any(ax is not None for ax in spec) for spec in flat)


def test_opt_state_inherits_param_spec():
    from repro import optim
    cfg = get_config("qwen2-0.5b")
    shapes = lm.param_shapes(cfg)
    pspecs = param_specs(cfg, shapes, MESH)
    opt = optim.adamw(1e-4)
    oshapes = jax.eval_shape(opt.init, shapes)
    ospecs = opt_state_specs(oshapes, pspecs, MESH)
    flat = jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(x, P))
    assert any(s == P(None, "data", "model") for s in flat)  # moments
