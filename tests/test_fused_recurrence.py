"""Fused one-kernel WBS×MiRU recurrence vs the per-timestep device scan.

The contract (kernels/wbs_miru_scan.py, backends/wbs.py): on substrates
with a WBS drive and the fused output ADC, ``device_recurrence`` runs the
whole quantized recurrence as one hoisted input projection + one fused
scan, and the result is **bit-identical** to the per-step ``device_vmm``
loop — including under per-step plane-gain noise, whose PRNG chain the
fused path replays exactly. Telemetry counters must also be exactly
equal between the two paths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import DeviceSpec, get_backend
from repro.core.continual import (ReplaySpec, TrainerSpec,
                                  miru_forward_device, run_continual)
from repro.core.miru import MiRUConfig, init_miru_params
from repro.kernels import ops, ref


def _forward_pair(B, T, K, H, n_bits=8, adc_bits=8, gain_sigma=0.0,
                  backend_name="wbs", seed=0):
    cfg = MiRUConfig(n_x=K, n_h=H, n_y=4)
    params = init_miru_params(jax.random.PRNGKey(seed), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(seed + 10), (B, T, K),
                           minval=-1, maxval=1)
    key = jax.random.PRNGKey(seed + 20)
    spec = DeviceSpec(input_bits=n_bits, adc_bits=adc_bits, adc_range=4.0,
                      weight_clip=1.5, gain_sigma=gain_sigma)
    backend = get_backend(backend_name, spec=spec)
    fused = jax.jit(lambda p, xs, k:
                    miru_forward_device(p, cfg, xs, k, backend, fused=True))
    step = jax.jit(lambda p, xs, k:
                   miru_forward_device(p, cfg, xs, k, backend, fused=False))
    return fused(params, x, key), step(params, x, key)


def _assert_bitwise(got, want):
    (l1, a1), (l2, a2) = got, want
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    for k in a1:
        np.testing.assert_array_equal(np.asarray(a1[k]),
                                      np.asarray(a2[k]), err_msg=k)


# ---------------------------------------------------------------------------
# Bit-exactness: fused vs per-step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,k,h", [
    (32, 28, 28, 100),      # the paper's 28×100×10 config
    (3, 5, 12, 37),         # ragged B/H needing padding
    (1, 1, 5, 8),           # degenerate single step
    (5, 11, 7, 130),        # H just past one 128 lane tile
    (2, 33, 3, 64),
])
@pytest.mark.parametrize("n_bits", [4, 8])
def test_fused_bitwise_identical(b, t, k, h, n_bits):
    got, want = _forward_pair(b, t, k, h, n_bits=n_bits)
    _assert_bitwise(got, want)


@pytest.mark.parametrize("adc_bits", [8, 6])
def test_fused_bitwise_identical_adc_widths(adc_bits):
    got, want = _forward_pair(4, 9, 12, 48, adc_bits=adc_bits)
    _assert_bitwise(got, want)


def test_fused_bitwise_identical_under_gain_noise():
    """gain_sigma > 0: the fused path replays the per-step (k, k1, k2)
    split chain, so even the stochastic plane-gain draws are identical."""
    for name in ("wbs", "analog"):
        got, want = _forward_pair(4, 7, 12, 32, gain_sigma=0.02,
                                  backend_name=name)
        _assert_bitwise(got, want)


def test_fused_falls_back_without_adc():
    """adc_bits=None (the cmos digital accumulator): no ADC to absorb
    sub-LSB fp scheduling, so the backend keeps the per-step path — the
    two entry points must be the *same* computation."""
    spec = DeviceSpec(input_bits=8, adc_bits=None, weight_clip=1.5)
    backend = get_backend("wbs", spec=spec)
    assert not backend._fused_recurrence_ok(None)
    got, want = _forward_pair(3, 5, 12, 37, adc_bits=None)
    _assert_bitwise(got, want)


def test_analog_read_sigma_disables_fusion():
    """Per-access conductance read noise cannot be hoisted into a
    VMEM-resident tile; the analog backend must refuse to fuse."""
    from repro.analog.crossbar import CrossbarSpec
    spec = DeviceSpec(input_bits=8, adc_bits=8, weight_clip=1.5,
                      crossbar=CrossbarSpec(read_sigma=0.05, w_clip=1.5))
    backend = get_backend("analog", spec=spec)
    assert not backend._fused_recurrence_ok(None)
    assert get_backend("analog")._fused_recurrence_ok(None)


def test_analog_state_never_fuses():
    backend = get_backend("analog_state")
    assert not backend._fused_recurrence_ok(None)


def test_backend_flag_respected_when_trainer_defers(monkeypatch):
    """TrainerSpec.fused_recurrence defaults to None = defer to the
    backend, so a backend constructed with fused_recurrence=False keeps
    the per-step path under a default trainer — and fused=True overrides
    the backend's opt-out. Dispatch is observed directly (the two paths
    are bit-identical, so output equality cannot distinguish them)."""
    assert TrainerSpec().fused_recurrence is None

    hits = []
    real = ops.wbs_miru_scan
    monkeypatch.setattr(ops, "wbs_miru_scan",
                        lambda *a, **kw: hits.append(1) or real(*a, **kw))
    cfg = MiRUConfig(n_x=8, n_h=16, n_y=3)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 3, 8),
                           minval=-1, maxval=1)

    opted_out = get_backend("wbs", fused_recurrence=False)
    miru_forward_device(params, cfg, x, jax.random.PRNGKey(2), opted_out,
                        fused=None)
    assert not hits                      # backend's False honored
    miru_forward_device(params, cfg, x, jax.random.PRNGKey(2), opted_out,
                        fused=True)
    assert hits                          # explicit trainer True overrides
    hits.clear()
    miru_forward_device(params, cfg, x, jax.random.PRNGKey(2),
                        get_backend("wbs"), fused=None)
    assert hits                          # default backend fuses


# ---------------------------------------------------------------------------
# Telemetry: counters exactly equal between the two paths
# ---------------------------------------------------------------------------

def test_fused_telemetry_counters_equal():
    cfg = MiRUConfig(n_x=12, n_h=32, n_y=5)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 7, 12),
                           minval=-1, maxval=1)
    snaps = {}
    for fused in (True, False):
        backend = get_backend("analog")
        backend.telemetry.enable()
        f = jax.jit(lambda p, xs, k: miru_forward_device(
            p, cfg, xs, k, backend, fused=fused)[0])
        f(params, x, jax.random.PRNGKey(3)).block_until_ready()
        snaps[fused] = backend.telemetry.snapshot()
    assert snaps[True] == snaps[False]
    # Spot-check the hand-computed totals: B=4, T=7, K=12, H=32, nb=8.
    assert snaps[True]["vmm_rows/w_h"] == 4 * 7
    assert snaps[True]["macs/u_h"] == 4 * 7 * 32 * 32
    assert snaps[True]["bit_pulses/w_h"] == 4 * 7 * 12 * 8
    assert snaps[True]["adc_conversions/hidden"] == 4 * 7 * 32


# ---------------------------------------------------------------------------
# Kernel-level: Pallas interpret mode vs the jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h", [(1, 1, 8), (3, 5, 37), (8, 9, 128),
                                   (5, 4, 130)])
@pytest.mark.parametrize("adc_bits", [8, None])
def test_wbs_miru_scan_kernel_vs_ref(b, t, h, adc_bits):
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + t + h), 3)
    drive = jax.random.normal(ks[0], (b, t, h))
    u = jax.random.normal(ks[1], (h, h)) * 0.3
    b_h = jax.random.normal(ks[2], (h,)) * 0.1
    kw = dict(beta=0.8, lam=0.5, n_bits=8, adc_bits=adc_bits,
              adc_range=4.0, weight_scale=1.5)
    got = ops.wbs_miru_scan(drive, u, b_h, use_kernel=True, **kw)
    want = ops.wbs_miru_scan(drive, u, b_h, use_kernel=False, **kw)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


def test_wbs_miru_scan_kernel_per_step_gains():
    """The (T, n_bits) per-step gains input streams through the kernel's
    BlockSpec — one gain row per timestep."""
    B, T, H, nb = 4, 6, 40, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    drive = jax.random.normal(ks[0], (B, T, H))
    u = jax.random.normal(ks[1], (H, H)) * 0.3
    b_h = jnp.zeros((H,))
    gains = (2.0 ** (-jnp.arange(1, nb + 1, dtype=jnp.float32)))[None, :] \
        * (1.0 + 0.05 * jax.random.normal(ks[2], (T, nb)))
    kw = dict(beta=0.8, lam=0.5, n_bits=nb, adc_bits=8, adc_range=4.0,
              weight_scale=1.5, gains=gains)
    got = ops.wbs_miru_scan(drive, u, b_h, use_kernel=True, **kw)
    want = ops.wbs_miru_scan(drive, u, b_h, use_kernel=False, **kw)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


def test_wbs_input_drive_matches_per_step_vmm():
    """The hoisted (B·T, K) projection equals T per-step wbs_vmm calls
    bit-for-bit."""
    from repro.analog.wbs import WBSSpec, wbs_vmm
    B, T, K, H, nb = 3, 5, 12, 37, 8
    x = jax.random.uniform(jax.random.PRNGKey(1), (B, T, K),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(2), (K, H)) * 0.3
    wspec = WBSSpec(n_bits=nb, gain_sigma=0.0, adc_bits=None)
    per_t = jax.jit(lambda x, w: jnp.stack(
        [wbs_vmm(x[:, t], w / 1.5, wspec) * 1.5 for t in range(T)], axis=1))
    hoisted = jax.jit(lambda x, w: ops.wbs_input_drive(
        x, w, nb, weight_scale=1.5))
    np.testing.assert_array_equal(np.asarray(per_t(x, w)),
                                  np.asarray(hoisted(x, w)))


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="compiled-kernel parity needs a TPU target")
def test_fused_kernel_bitwise_on_accelerator():
    """On compiled targets both paths run Pallas kernels with identical
    per-plane accumulation order — bitwise, not just allclose."""
    got, want = _forward_pair(8, 8, 16, 128)
    _assert_bitwise(got, want)


# ---------------------------------------------------------------------------
# Gradients and end-to-end training
# ---------------------------------------------------------------------------

def test_fused_adam_gradients_close():
    """BPTT through the fused scan: the STE custom-VJP matches the
    per-step STE composition (same linearized graph; accumulation order
    differs, so allclose rather than bitwise)."""
    from repro.utils import softmax_cross_entropy
    cfg = MiRUConfig(n_x=12, n_h=32, n_y=5)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 7, 12),
                           minval=-1, maxval=1)
    labels = jnp.zeros((4,), jnp.int32)
    backend = get_backend("wbs")
    grads = {}
    for fused in (True, False):
        def loss(p, fused=fused):
            logits, _ = miru_forward_device(p, cfg, x, jax.random.PRNGKey(0),
                                            backend, fused=fused)
            return softmax_cross_entropy(logits, labels)
        grads[fused] = jax.grad(loss)(params)
    for k in grads[True]:
        assert float(jnp.abs(grads[True][k]).max()) > 0 or k in ("b_h",), k
        np.testing.assert_allclose(np.asarray(grads[True][k]),
                                   np.asarray(grads[False][k]),
                                   rtol=1e-4, atol=1e-6, err_msg=k)


@pytest.mark.slow
def test_fused_dfa_run_bitwise_identical():
    """Whole continual-learning run (DFA + replay + noisy analog writes):
    fused and per-step recurrences produce bit-identical R, losses and
    final weights — DFA's gradients are pure functions of the forward
    intermediates, which are bitwise equal."""
    from repro.data.synthetic import make_permuted_tasks
    tasks = make_permuted_tasks(0, n_tasks=2, n_train=96, n_test=48)
    cfg = MiRUConfig(n_x=tasks[0].x_train.shape[2], n_h=40, n_y=10)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=32)
    r1 = run_continual(cfg, trainer, tasks, replay=ReplaySpec(capacity=64),
                       device="analog")
    r2 = run_continual(cfg,
                       dataclasses.replace(trainer, fused_recurrence=False),
                       tasks, replay=ReplaySpec(capacity=64),
                       device="analog")
    np.testing.assert_array_equal(r1["R"], r2["R"])
    assert r1["losses"] == r2["losses"]
    for k in r1["params"]:
        np.testing.assert_array_equal(np.asarray(r1["params"][k]),
                                      np.asarray(r2["params"][k]))


def test_legacy_continual_config_carries_fused_flag():
    from repro.core.continual import ContinualConfig
    trainer, _, _ = ContinualConfig(trainer="dfa_hw",
                                    fused_recurrence=False).specs()
    assert trainer.fused_recurrence is False
    trainer, _, _ = ContinualConfig(trainer="dfa_hw").specs()
    assert trainer.fused_recurrence is None    # defer to the backend
