"""The device-backend subsystem: registry, substrate implementations, the
backend-parameterized forward, and the legacy ContinualConfig shim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analog.crossbar import CrossbarSpec
from repro.backends import (AnalogBackend, DeviceBackend, DeviceSpec,
                            IdealBackend, WBSBackend, available_backends,
                            get_backend, register_backend,
                            unregister_backend)
from repro.core.continual import (ContinualConfig, ReplaySpec, TrainerSpec,
                                  miru_forward_device, run_continual)
from repro.core.miru import MiRUConfig, init_miru_params, miru_forward

CFG = MiRUConfig(n_x=12, n_h=32, n_y=5)


@pytest.fixture(scope="module")
def params():
    return init_miru_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def x_seq():
    return jax.random.uniform(jax.random.PRNGKey(1), (4, 7, CFG.n_x),
                              minval=-1, maxval=1)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    assert {"ideal", "wbs", "analog"} <= set(available_backends())


def test_get_backend_returns_fresh_instances():
    a, b = get_backend("ideal"), get_backend("ideal")
    assert isinstance(a, IdealBackend) and a is not b


def test_get_backend_passthrough_instance():
    b = get_backend("wbs")
    assert get_backend(b) is b
    with pytest.raises(ValueError):
        get_backend(b, spec=DeviceSpec())
    with pytest.raises(ValueError):
        get_backend(b, use_kernel=False)


def test_device_vmm_registry_dispatch():
    from repro.kernels import ops
    x = jax.random.uniform(jax.random.PRNGKey(0), (4, 8),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 3)) * 0.3
    np.testing.assert_array_equal(np.asarray(ops.device_vmm(x, w, "ideal")),
                                  np.asarray(x @ w))
    y = ops.device_vmm(x, w, "wbs",
                       spec_overrides=dict(input_bits=8, weight_clip=None))
    assert float(jnp.abs(y - x @ w).max()) < 0.05
    with pytest.raises(ValueError, match="unknown device backend"):
        ops.device_vmm(x, w, "nope")


def test_spec_overrides_preserve_backend_physics():
    b = get_backend("analog", spec_overrides=dict(input_bits=6,
                                                  adc_bits=None))
    assert (b.spec.input_bits, b.spec.adc_bits) == (6, None)
    # Everything not overridden keeps the analog default physics.
    d = AnalogBackend.default_spec()
    assert b.spec.gain_sigma == d.gain_sigma
    assert b.spec.crossbar == d.crossbar


def test_unknown_backend_raises_with_names():
    with pytest.raises(ValueError, match="ideal"):
        get_backend("flux-capacitor")


def test_register_roundtrip():
    @register_backend("test-null")
    class NullBackend(IdealBackend):
        name = "test-null"

    try:
        assert "test-null" in available_backends()
        assert isinstance(get_backend("test-null"), NullBackend)
    finally:
        unregister_backend("test-null")
    assert "test-null" not in available_backends()


# ---------------------------------------------------------------------------
# Ideal backend ≡ the software forward (the refactor's bit-exactness bar)
# ---------------------------------------------------------------------------

def test_ideal_forward_bit_matches_software(params, x_seq):
    logits0, aux0 = miru_forward(params, CFG, x_seq)
    logits1, aux1 = miru_forward_device(params, CFG, x_seq,
                                        jax.random.PRNGKey(9),
                                        get_backend("ideal"))
    np.testing.assert_array_equal(np.asarray(logits0), np.asarray(logits1))
    for k in aux0:
        np.testing.assert_array_equal(np.asarray(aux0[k]),
                                      np.asarray(aux1[k]))


def test_ideal_forward_bit_matches_under_jit(params, x_seq):
    backend = get_backend("ideal")
    f0 = jax.jit(lambda p, xs: miru_forward(p, CFG, xs)[0])
    f1 = jax.jit(lambda p, k, xs:
                 miru_forward_device(p, CFG, xs, k, backend)[0])
    np.testing.assert_array_equal(
        np.asarray(f0(params, x_seq)),
        np.asarray(f1(params, jax.random.PRNGKey(3), x_seq)))


def test_ideal_apply_update_is_exact(params):
    backend = get_backend("ideal")
    updates = jax.tree.map(lambda p: jnp.full_like(p, 0.125), params)
    new, applied = backend.apply_update(params, updates, None)
    for k in params:
        np.testing.assert_array_equal(np.asarray(new[k]),
                                      np.asarray(params[k] + 0.125))
        np.testing.assert_array_equal(np.asarray(applied[k]),
                                      np.asarray(updates[k]))


# ---------------------------------------------------------------------------
# WBS backend — quantized drive + ADC, no device noise
# ---------------------------------------------------------------------------

def test_wbs_vmm_tracks_matmul():
    backend = get_backend("wbs", spec=DeviceSpec(input_bits=8,
                                                 weight_clip=None))
    x = jax.random.uniform(jax.random.PRNGKey(2), (16, 24),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(3), (24, 8)) * 0.3
    y = backend.vmm(x, w)
    rel = float(jnp.abs(y - x @ w).max() / jnp.abs(x @ w).max())
    assert rel < 0.02, rel
    # Deterministic without a key.
    np.testing.assert_array_equal(np.asarray(y), np.asarray(backend.vmm(x, w)))


def test_quantized_backends_pass_gradients_through(params, x_seq):
    """BPTT through wbs/analog must see straight-through gradients — the
    sign-magnitude and ADC rounding would otherwise zero every hidden
    gradient, silently training only the readout under algo='adam'."""
    from repro.utils import softmax_cross_entropy
    labels = jnp.zeros((x_seq.shape[0],), jnp.int32)
    for name in ("wbs", "analog"):
        backend = get_backend(name)

        def loss(p):
            logits, _ = miru_forward_device(p, CFG, x_seq,
                                            jax.random.PRNGKey(0), backend)
            return softmax_cross_entropy(logits, labels)

        grads = jax.grad(loss)(params)
        for k in ("w_h", "u_h", "b_h"):
            assert float(jnp.abs(grads[k]).max()) > 0, (name, k)


def test_wbs_readout_adc_quantizes():
    backend = get_backend("wbs", spec=DeviceSpec(adc_bits=4, adc_range=2.0))
    pre = jnp.linspace(-3.0, 3.0, 64)
    q = backend.quantize_readout(pre)
    step = 2.0 * 2.0 / 2 ** 4
    np.testing.assert_allclose(np.asarray(q) / step,
                               np.round(np.asarray(q) / step), atol=1e-6)


def test_wbs_apply_update_clips():
    backend = get_backend("wbs", spec=DeviceSpec(weight_clip=1.0))
    p = {"w": jnp.array([0.9, -0.9])}
    new, applied = backend.apply_update(p, {"w": jnp.array([0.5, -0.5])})
    np.testing.assert_allclose(np.asarray(new["w"]), [1.0, -1.0])
    np.testing.assert_allclose(np.asarray(applied["w"]), [0.1, -0.1],
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Analog backend — CrossbarSpec-driven write physics + endurance
# ---------------------------------------------------------------------------

def test_analog_write_levels_snap_to_grid():
    spec = DeviceSpec(weight_clip=1.0,
                      crossbar=CrossbarSpec(write_sigma=0.0, w_clip=1.0,
                                            write_levels=5))
    backend = get_backend("analog", spec=spec)
    p = {"w": jnp.array([0.0, 0.2, -0.6, 0.9])}
    dw = {"w": jnp.array([0.3, 0.0, -0.1, 0.0])}
    new, _ = backend.apply_update(p, dw, jax.random.PRNGKey(0))
    got = np.asarray(new["w"])
    grid = np.linspace(-1.0, 1.0, 5)        # 5 levels, step 0.5
    # Written entries snap to the grid; untouched entries keep their value.
    assert np.isclose(got[0], grid).any() and np.isclose(got[2], grid).any()
    np.testing.assert_allclose(got[[1, 3]], [0.2, 0.9])


def test_analog_write_noise_only_on_written_entries():
    backend = get_backend("analog")
    p = {"w": jnp.zeros((8, 8))}
    dw = {"w": jnp.zeros((8, 8)).at[0, 0].set(0.1)}
    new, applied = backend.apply_update(p, dw, jax.random.PRNGKey(4))
    a = np.asarray(applied["w"])
    assert a[0, 0] != 0 and abs(a[0, 0] - 0.1) < 0.1   # noisy ±10 % write
    assert (a.reshape(-1)[1:] == 0).all()


def test_analog_records_endurance():
    spec = dataclasses.replace(AnalogBackend.default_spec(),
                               track_endurance=True)
    backend = get_backend("analog", spec=spec)
    assert backend.tracker is not None
    p = {"w_h": jnp.zeros((4, 4))}
    dw = {"w_h": jnp.zeros((4, 4)).at[1, 2].set(0.05)}
    _, applied = backend.apply_update(p, dw, jax.random.PRNGKey(5))
    backend.record_endurance(applied)
    assert backend.tracker.updates_applied == 1
    counts = backend.tracker.all_counts()
    assert counts.sum() == 1


def test_analog_requires_write_key():
    backend = get_backend("analog")
    with pytest.raises(ValueError, match="PRNG key"):
        backend.apply_update({"w": jnp.zeros(3)}, {"w": jnp.zeros(3)}, None)


# ---------------------------------------------------------------------------
# Legacy ContinualConfig shim
# ---------------------------------------------------------------------------

def test_shim_maps_old_trainer_strings():
    for trainer, algo, cls in (("adam", "adam", IdealBackend),
                               ("dfa", "dfa", IdealBackend),
                               ("dfa_hw", "dfa", AnalogBackend)):
        tspec, rspec, backend = ContinualConfig(trainer=trainer).specs()
        assert tspec.algo == algo
        assert isinstance(backend, cls)
        assert isinstance(rspec, ReplaySpec)


def test_shim_maps_old_kwargs_onto_specs():
    ccfg = ContinualConfig(trainer="dfa_hw", epochs_per_task=3,
                           batch_size=16, lr=0.1, replay_capacity=64,
                           replay_ratio=0.25, replay_bits=8, input_bits=6,
                           adc_bits=5, gain_sigma=0.03, write_sigma=0.2,
                           weight_clip=2.0, track_endurance=True, seed=11)
    tspec, rspec, backend = ccfg.specs()
    assert (tspec.epochs_per_task, tspec.batch_size, tspec.lr,
            tspec.seed) == (3, 16, 0.1, 11)
    assert (rspec.capacity, rspec.ratio, rspec.bits) == (64, 0.25, 8)
    s = backend.spec
    assert (s.input_bits, s.adc_bits, s.gain_sigma) == (6, 5, 0.03)
    assert s.crossbar.write_sigma == 0.2 and s.weight_clip == 2.0
    assert backend.tracker is not None


def test_shim_unknown_trainer_raises():
    with pytest.raises(ValueError, match="unknown trainer"):
        ContinualConfig(trainer="sgd_hw").specs()


def test_legacy_and_new_api_runs_bit_identical():
    """run_continual(ContinualConfig) ≡ run_continual(TrainerSpec, …) —
    the shim is a pure re-parameterization, not a second code path."""
    from repro.data.synthetic import make_permuted_tasks
    tasks = make_permuted_tasks(0, n_tasks=2, n_train=96, n_test=64)
    cfg = MiRUConfig(n_x=28, n_h=24, n_y=10)
    ccfg = ContinualConfig(trainer="dfa_hw", epochs_per_task=1)
    with pytest.deprecated_call():
        r_legacy = run_continual(cfg, ccfg, tasks)
    tspec, rspec, backend = ccfg.specs()
    r_new = run_continual(cfg, tspec, tasks, replay=rspec, device=backend)
    np.testing.assert_array_equal(r_legacy["R"], r_new["R"])


def test_run_continual_rejects_mixed_legacy_and_new():
    from repro.data.synthetic import make_permuted_tasks
    tasks = make_permuted_tasks(0, n_tasks=2, n_train=64, n_test=32)
    with pytest.raises(ValueError, match="not both"):
        run_continual(MiRUConfig(n_x=28, n_h=8, n_y=10),
                      ContinualConfig(), tasks, device="ideal")


# ---------------------------------------------------------------------------
# Replay seeding fix: task 0 offers the full fresh batch to the reservoir
# ---------------------------------------------------------------------------

def test_task0_buffer_seeded_from_full_batches(monkeypatch):
    from repro.core import replay as replay_mod
    offered = []
    orig = replay_mod.ReplayBuffer.add_batch

    def spy(self, xs, ys, task_ids=None):
        offered.append(len(xs))
        return orig(self, xs, ys, task_ids=task_ids)

    monkeypatch.setattr(replay_mod.ReplayBuffer, "add_batch", spy)
    from repro.data.synthetic import make_permuted_tasks
    tasks = make_permuted_tasks(0, n_tasks=2, n_train=64, n_test=32)
    run_continual(MiRUConfig(n_x=28, n_h=8, n_y=10),
                  TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=32),
                  tasks, replay=ReplaySpec(ratio=0.5), device="ideal")
    n_batches_per_task = 64 // 32
    # Task 0: full batches (32) offered; task 1: only the fresh half (16).
    assert offered[:n_batches_per_task] == [32] * n_batches_per_task
    assert offered[n_batches_per_task:] == [16] * n_batches_per_task


# ---------------------------------------------------------------------------
# A custom registered backend drives the full continual loop
# ---------------------------------------------------------------------------

def test_custom_backend_runs_continual():
    @register_backend("test-sticky")
    class StickyBackend(DeviceBackend):
        """Wildly non-ideal device: writes only land at half strength."""
        name = "test-sticky"

        def vmm(self, drive, weights, key=None):
            return drive @ weights

        def apply_update(self, params, updates, key=None):
            new = {k: p + 0.5 * updates[k] for k, p in params.items()}
            return new, {k: new[k] - p for k, p in params.items()}

    try:
        from repro.data.synthetic import make_permuted_tasks
        tasks = make_permuted_tasks(0, n_tasks=2, n_train=64, n_test=32)
        res = run_continual(MiRUConfig(n_x=28, n_h=8, n_y=10),
                            TrainerSpec(algo="dfa", epochs_per_task=1),
                            tasks, device="test-sticky")
        assert res["R"].shape == (2, 2)
        assert np.isfinite(res["MA"])
    finally:
        unregister_backend("test-sticky")
