"""repro.scenarios: registry contents, builder contracts, CL metrics,
and the compiled sweep's bit-parity with the per-task Python loop."""
import dataclasses

import numpy as np
import pytest

from repro.backends import get_backend
from repro.core.continual import (ReplaySpec, TrainerSpec,
                                  build_batch_schedule, run_continual)
from repro.data.synthetic import TaskData
from repro.scenarios import (available_scenarios, backward_transfer,
                             build_scenario, continual_metrics, forgetting,
                             forward_transfer, get_scenario,
                             register_scenario, run_compiled, run_sweep,
                             scenario_miru_config, unregister_scenario)

SMALL = dict(n_tasks=3, n_train=96, n_test=48)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_exposes_the_scenario_suite():
    names = set(available_scenarios())
    assert {"permuted", "split", "rotated", "noisy_label", "drift",
            "class_incremental", "streaming"} <= names
    assert len(names) >= 6


def test_unknown_scenario_raises():
    with pytest.raises(ValueError, match="unknown scenario"):
        build_scenario("not-a-scenario")


def test_register_unregister_roundtrip():
    @register_scenario("tmp_scn", description="test only")
    def _mk(seed, n_tasks=2, n_train=8, n_test=4):
        return build_scenario("permuted", seed, n_tasks=n_tasks,
                              n_train=n_train, n_test=n_test)

    try:
        assert "tmp_scn" in available_scenarios()
        tasks = build_scenario("tmp_scn", 0)
        assert len(tasks) == 2
    finally:
        unregister_scenario("tmp_scn")
    assert "tmp_scn" not in available_scenarios()


@pytest.mark.parametrize("name", ["permuted", "split", "rotated",
                                  "noisy_label", "drift",
                                  "class_incremental", "streaming"])
def test_builder_contract(name):
    """Every scenario emits the TaskData shape the trainer consumes:
    float32 x in [0, 1] with (N, T, F), int32 labels, sequential ids."""
    tasks = build_scenario(name, seed=0, **SMALL)
    assert len(tasks) == SMALL["n_tasks"]
    for t, task in enumerate(tasks):
        assert isinstance(task, TaskData)
        assert task.task_id == t
        assert task.x_train.ndim == 3 and task.x_test.ndim == 3
        assert task.x_train.dtype == np.float32
        assert task.y_train.dtype == np.int32
        assert task.x_train.shape[0] == len(task.y_train)
        assert float(task.x_train.min()) >= 0.0
        assert float(task.x_train.max()) <= 1.0


# ---------------------------------------------------------------------------
# Scenario-specific structure
# ---------------------------------------------------------------------------

def test_rotated_task0_is_identity_and_rotation_changes_view():
    tasks = build_scenario("rotated", seed=3, **SMALL)
    base = build_scenario("permuted", seed=3, n_tasks=1,
                          n_train=SMALL["n_train"], n_test=SMALL["n_test"])
    # Task 0 (angle 0) is the raw dataset — identical to the permuted
    # builder's identity task for the same seed.
    np.testing.assert_array_equal(tasks[0].x_train, base[0].x_train)
    assert not np.allclose(tasks[0].x_train, tasks[-1].x_train)
    # Rotation reorients the same images: labels stay the base draw's.
    np.testing.assert_array_equal(tasks[0].y_train, tasks[-1].y_train)


def test_noisy_label_flip_rate_ramps():
    kw = dict(n_tasks=4, n_train=600, n_test=32)
    noisy = build_scenario("noisy_label", seed=5, max_flip=0.4, **kw)
    clean = build_scenario("noisy_label", seed=5, max_flip=0.0, **kw)
    rates = np.linspace(0.0, 0.4, 4)
    for t, (a, b) in enumerate(zip(noisy, clean)):
        # Same RNG consumption → identical features; labels differ exactly
        # at the flipped positions (the shift never maps to itself).
        np.testing.assert_array_equal(a.x_train, b.x_train)
        np.testing.assert_array_equal(a.y_test, b.y_test)  # test stays clean
        frac = float((a.y_train != b.y_train).mean())
        assert abs(frac - rates[t]) < 0.08, (t, frac)


def test_drift_is_gradual():
    tasks = build_scenario("drift", seed=1, n_tasks=5, n_train=400,
                           n_test=32)

    def class_means(task):
        x = task.x_train.reshape(len(task.y_train), -1)
        return np.stack([x[task.y_train == c].mean(0) for c in range(10)])

    m = [class_means(t) for t in tasks]
    step = np.linalg.norm(m[1] - m[0])
    span = np.linalg.norm(m[-1] - m[0])
    assert step < 0.5 * span          # neighbors overlap, endpoints don't


def test_class_incremental_global_labels():
    tasks = build_scenario("class_incremental", seed=0, **SMALL,
                           classes_per_task=2)
    for t, task in enumerate(tasks):
        labels = set(np.unique(task.y_train)) | set(np.unique(task.y_test))
        assert labels <= {2 * t, 2 * t + 1}
    cfg = scenario_miru_config(tasks, n_h=16)
    assert cfg.n_y == 2 * SMALL["n_tasks"]    # full expanding head


def test_streaming_is_single_pass_and_restart_safe():
    spec = get_scenario("streaming")
    assert spec.trainer_overrides == {"epochs_per_task": 1}
    a = build_scenario("streaming", seed=9, **SMALL)
    b = build_scenario("streaming", seed=9, **SMALL)
    for ta, tb in zip(a, b):
        np.testing.assert_array_equal(ta.x_train, tb.x_train)
        np.testing.assert_array_equal(ta.y_train, tb.y_train)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_metrics_on_known_matrix():
    R = np.array([[0.9, 0.5, 0.1],
                  [0.8, 0.9, 0.2],
                  [0.6, 0.7, 0.9]])
    m = continual_metrics(R, baseline=np.array([0.1, 0.1, 0.1]))
    assert m["average_accuracy"] == pytest.approx((0.6 + 0.7 + 0.9) / 3)
    # BWT over i<2: (0.6-0.9 + 0.7-0.9)/2 = -0.25
    assert m["backward_transfer"] == pytest.approx(-0.25)
    # Forgetting: (max(0.9,0.8)-0.6 + 0.9-0.7)/2 = 0.25
    assert m["forgetting"] == pytest.approx(0.25)
    # FWT: (R[0,1]-b1 + R[1,2]-b2)/2 = (0.4 + 0.1)/2
    assert m["forward_transfer"] == pytest.approx(0.25)


def test_metrics_single_task_edges():
    R = np.array([[0.7]])
    assert backward_transfer(R) == 0.0
    assert forgetting(R) == 0.0
    assert forward_transfer(R, np.array([0.1])) == 0.0


def test_metrics_reject_bad_shapes():
    with pytest.raises(ValueError):
        forgetting(np.zeros((2, 3)))
    with pytest.raises(ValueError):
        forward_transfer(np.eye(3), np.zeros(2))


# ---------------------------------------------------------------------------
# Compiled sweep vs the per-task Python loop
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def parity_setup():
    tasks = build_scenario("permuted", seed=0, n_tasks=3, n_train=128,
                           n_test=64)
    cfg = scenario_miru_config(tasks, n_h=64)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=2)
    rspec = ReplaySpec(capacity=96)
    return cfg, trainer, rspec, tasks


def test_compiled_matches_loop_bit_for_bit(parity_setup):
    """The acceptance gate: scan-over-tasks on the ideal backend returns
    the Python loop's accuracies exactly — same batch schedule, same PRNG
    streams, same step functions."""
    cfg, trainer, rspec, tasks = parity_setup
    loop = run_continual(cfg, trainer, tasks, replay=rspec, device="ideal")
    comp = run_compiled(cfg, trainer, tasks, replay=rspec, device="ideal")
    assert comp["compiled"]
    np.testing.assert_array_equal(loop["R"], comp["R"])
    assert loop["MA"] == comp["MA"]
    np.testing.assert_allclose(loop["losses"], comp["losses"],
                               rtol=2e-5, atol=1e-6)


def test_compiled_full_matrix_and_baseline(parity_setup):
    cfg, trainer, rspec, tasks = parity_setup
    comp = run_compiled(cfg, trainer, tasks, replay=rspec, device="ideal")
    R, R_full = comp["R"], comp["R_full"]
    iu = np.triu_indices(3, 1)
    assert np.all(R[iu] == 0)                 # loop-compatible view
    assert np.any(R_full[iu] > 0)             # unseen-task evals populated
    assert {"average_accuracy", "backward_transfer", "forgetting",
            "forward_transfer"} <= set(comp["metrics"])
    assert np.all(comp["baseline_row"] >= 0)
    assert float(np.max(comp["baseline_row"])) < 0.6   # untrained ≈ chance


def test_compiled_shares_schedule_with_loop(parity_setup):
    cfg, trainer, rspec, tasks = parity_setup
    s1 = build_batch_schedule(trainer, rspec, tasks)
    s2 = build_batch_schedule(trainer, rspec, tasks)
    assert s1.uniform
    for a, b in zip(s1.x, s2.x):
        np.testing.assert_array_equal(a, b)


def test_compiled_adam_path(parity_setup):
    cfg, _, rspec, tasks = parity_setup
    trainer = TrainerSpec(algo="adam", epochs_per_task=1)
    loop = run_continual(cfg, trainer, tasks, replay=rspec, device="ideal")
    comp = run_compiled(cfg, trainer, tasks, replay=rspec, device="ideal")
    np.testing.assert_array_equal(loop["R"], comp["R"])
    assert loop["MA"] == comp["MA"]


def test_compiled_metered_device_backend(parity_setup):
    """Telemetry threads through the scans: counters land once per
    compiled execution with the scan multiplicities applied, and the
    write pulses/endurance map summed inside the scan match the
    data-dependent accounting."""
    cfg, trainer, rspec, tasks = parity_setup
    backend = get_backend("analog_state",
                          spec_overrides=dict(track_endurance=True))
    backend.telemetry.enable()
    comp = run_compiled(cfg, trainer, tasks, replay=rspec, device=backend)
    snap = backend.telemetry.snapshot()
    n_steps = 3 * comp["steps_per_task"]
    assert snap["write_events"] == n_steps
    assert comp["endurance"].updates_applied == n_steps
    assert comp["endurance"].mean_writes() > 0
    # Train forwards + (n_tasks+1)·n_tasks eval forwards, all ×T×B.
    B, T = trainer.batch_size, tasks[0].x_train.shape[1]
    n_test = tasks[0].x_test.shape[0]
    expect = n_steps * B * T + (3 * 3 + 3) * n_test * T
    assert backend.telemetry.total("sample_steps") == expect


def test_compiled_vmapped_seeds(parity_setup):
    cfg, trainer, rspec, tasks = parity_setup
    comp = run_compiled(cfg, dataclasses.replace(trainer,
                                                 epochs_per_task=1),
                        tasks, replay=rspec, device="ideal",
                        seeds=[0, 1, 2])
    assert comp["compiled"]
    assert len(comp["per_seed"]) == 3
    assert set(comp["metrics_std"]) == set(comp["metrics"])
    mas = [p["MA"] for p in comp["per_seed"]]
    assert len(set(mas)) > 1          # seeds actually vary the run
    # Seed 0's cell must equal the single-seed run of seed 0.
    single = run_compiled(cfg, dataclasses.replace(trainer,
                                                   epochs_per_task=1,
                                                   seed=0),
                          tasks, replay=rspec, device="ideal")
    np.testing.assert_array_equal(comp["per_seed"][0]["R"], single["R"])


def test_non_uniform_stream_falls_back_to_loop():
    @register_scenario("ragged_scn", uniform=False)
    def _mk(seed, n_tasks=2, n_train=64, n_test=32):
        a = build_scenario("permuted", seed, n_tasks=1, n_train=n_train,
                           n_test=n_test)[0]
        b = build_scenario("permuted", seed + 1, n_tasks=1,
                           n_train=n_train // 2, n_test=n_test)[0]
        return [a, dataclasses.replace(b, task_id=1)]

    try:
        tasks = build_scenario("ragged_scn", 0)
        cfg = scenario_miru_config(tasks, n_h=32)
        res = run_compiled(cfg, TrainerSpec(algo="dfa",
                                            epochs_per_task=1),
                           tasks, replay=ReplaySpec(capacity=32),
                           device="ideal")
        assert res["compiled"] is False
        assert res["R"].shape == (2, 2)
        assert "metrics" in res
    finally:
        unregister_scenario("ragged_scn")


def test_declared_non_uniform_skips_compilation():
    """ScenarioSpec.uniform=False is honored as a hint: run_compiled goes
    straight to the Python loop without materializing a schedule, even
    when the stream happens to be shape-uniform."""
    tasks = build_scenario("permuted", 0, n_tasks=2, n_train=64, n_test=32)
    cfg = scenario_miru_config(tasks, n_h=16)
    res = run_compiled(cfg, TrainerSpec(algo="dfa", epochs_per_task=1),
                       tasks, replay=ReplaySpec(capacity=32),
                       device="ideal", uniform=False)
    assert res["compiled"] is False
    assert res["R"].shape == (2, 2)


def test_run_sweep_grid_cells():
    grid = run_sweep(["permuted", "class_incremental"],
                     ["ideal", "analog_state"],
                     TrainerSpec(algo="dfa", epochs_per_task=1),
                     ReplaySpec(capacity=48), n_h=32,
                     scenario_kwargs=dict(n_tasks=2, n_train=64,
                                          n_test=32))
    cells = grid["cells"]
    assert set(cells) == {"permuted/ideal", "permuted/analog_state",
                          "class_incremental/ideal",
                          "class_incremental/analog_state"}
    for key, cell in cells.items():
        assert cell["compiled"], key
        assert 0.0 <= cell["MA"] <= 1.0
        assert "forgetting" in cell["metrics"]
    # Metered substrates carry live power/efficiency; ideal does not.
    assert "power_mw" in cells["permuted/analog_state"]
    assert cells["permuted/analog_state"]["power_mw"] > 0
    assert "power_mw" not in cells["permuted/ideal"]
