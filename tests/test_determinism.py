"""Seed determinism: batcher checkpoint/restart resumes the identical
stream, and scenario builders are bit-reproducible across processes."""
import hashlib
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.continual import (GOLDEN_PERMUTED_SCHEDULE_SHA256,
                                  ReplaySpec, TrainerSpec,
                                  build_batch_schedule)
from repro.data.pipeline import ShardedBatcher
from repro.scenarios import build_scenario


def _gen(rng, step):
    return {"x": rng.integers(0, 10 ** 6, size=16),
            "y": rng.standard_normal(4).astype(np.float32)}


def test_batcher_state_dict_roundtrip_resumes_identical_stream():
    """Serialize mid-stream (through JSON, like a checkpoint would),
    restore into a fresh batcher, and the continuation is bit-identical
    to an uninterrupted run."""
    ref = ShardedBatcher(_gen, seed=11)
    stream = [ref.next() for _ in range(10)]

    a = ShardedBatcher(_gen, seed=11)
    for _ in range(4):
        a.next()
    blob = json.dumps(a.state_dict())

    b = ShardedBatcher(_gen, seed=0)          # wrong seed on purpose
    b.load_state_dict(json.loads(blob))
    for i in range(4, 10):
        got = b.next()
        np.testing.assert_array_equal(got["x"], stream[i]["x"])
        np.testing.assert_array_equal(got["y"], stream[i]["y"])
    assert b.state_dict() == ref.state_dict()


def test_batcher_peek_is_pure():
    """peek(step) never advances state and equals the stream at step."""
    a = ShardedBatcher(_gen, seed=3)
    peeked = [a.peek(i) for i in range(5)]
    assert a.state.step == 0
    for i in range(5):
        np.testing.assert_array_equal(a.next()["x"], peeked[i]["x"])


_HASH_SNIPPET = """
import hashlib, sys
import numpy as np
from repro.scenarios import build_scenario

h = hashlib.sha256()
for name in ("permuted", "rotated", "streaming", "class_incremental"):
    for task in build_scenario(name, seed=123, n_tasks=2, n_train=48,
                               n_test=24):
        for arr in (task.x_train, task.y_train, task.x_test, task.y_test):
            h.update(np.ascontiguousarray(arr).tobytes())
print(h.hexdigest())
"""


def test_scenario_builders_bit_reproducible_across_processes():
    """The same seed yields byte-identical task streams in two fresh
    interpreter processes (no hidden global-RNG or hash-seed state)."""
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""),
               JAX_PLATFORMS="cpu")
    digests = []
    for run in range(2):
        env["PYTHONHASHSEED"] = str(run)      # must not matter
        out = subprocess.run([sys.executable, "-c", _HASH_SNIPPET],
                             capture_output=True, text=True, env=env,
                             cwd=os.path.dirname(os.path.dirname(
                                 os.path.abspath(__file__))),
                             timeout=300)
        assert out.returncode == 0, out.stderr
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1]
    assert len(digests[0]) == 64


def test_batch_schedule_deterministic_and_seed_sensitive():
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=16)
    tr = TrainerSpec(algo="dfa", epochs_per_task=1, seed=5)
    rs = ReplaySpec(capacity=32)
    s1 = build_batch_schedule(tr, rs, tasks)
    s2 = build_batch_schedule(tr, rs, tasks)
    for a, b in zip(s1.x + s1.y, s2.x + s2.y):
        np.testing.assert_array_equal(a, b)
    s3 = build_batch_schedule(
        TrainerSpec(algo="dfa", epochs_per_task=1, seed=6), rs, tasks)
    assert any(not np.array_equal(a, b) for a, b in zip(s1.x, s3.x))


@pytest.mark.parametrize("name", ["noisy_label", "drift", "split"])
def test_builders_in_process_reproducible(name):
    a = build_scenario(name, seed=42, n_tasks=2, n_train=40, n_test=16)
    b = build_scenario(name, seed=42, n_tasks=2, n_train=40, n_test=16)
    for ta, tb in zip(a, b):
        np.testing.assert_array_equal(ta.x_train, tb.x_train)
        np.testing.assert_array_equal(ta.y_train, tb.y_train)
        np.testing.assert_array_equal(ta.x_test, tb.x_test)
    c = build_scenario(name, seed=43, n_tasks=2, n_train=40, n_test=16)
    assert not np.array_equal(a[0].x_train, c[0].x_train)


def test_schedule_hash_matches_golden():
    """A pinned digest of the permuted schedule: any unintended change to
    the host RNG consumption order (epoch shuffle, reservoir offers,
    quantizer key chain) shows up here before it silently breaks
    loop/compiled bit-parity."""
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=16)
    sched = build_batch_schedule(
        TrainerSpec(algo="dfa", epochs_per_task=1, seed=0),
        ReplaySpec(capacity=32), tasks)
    digest = sched.digest()
    assert digest == GOLDEN_PERMUTED_SCHEDULE_SHA256, digest
    # The digest helper is what the bench-scenarios CI gate consumes;
    # pin its recipe against an inline hash so they can't drift apart.
    h = hashlib.sha256()
    for arr in sched.x + sched.y:
        h.update(np.ascontiguousarray(arr).tobytes())
    assert digest == h.hexdigest()
