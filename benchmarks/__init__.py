"""Benchmark harness — one module per paper table/figure + roofline.

Run everything:  PYTHONPATH=src python -m benchmarks.run
Each bench prints ``name,us_per_call,derived`` CSV rows and writes its
artifact (JSON) under benchmarks/results/.
"""
