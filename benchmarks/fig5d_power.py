"""Fig. 5d: power breakdown across core units (analytical circuit
model): analog front-end (ADCs + Op-Amps) dominates."""
from __future__ import annotations

import time

from repro.analog.costmodel import M2RUCostModel

from benchmarks.common import emit, save_json


def run() -> dict:
    m = M2RUCostModel()
    t0 = time.time()
    brk = m.power_breakdown_w()
    total = sum(brk.values())
    out = {"breakdown_mw": {k: v * 1e3 for k, v in brk.items()},
           "total_mw": total * 1e3,
           "training_mw": m.power_w(training=True) * 1e3,
           "shares": {k: v / total for k, v in brk.items()}}
    emit("fig5d/total", (time.time() - t0) * 1e6,
         f"total={total*1e3:.2f}mW(expect48.62)")
    for k, v in brk.items():
        emit(f"fig5d/{k}", 0.0, f"{v*1e3:.3f}mW({v/total*100:.1f}%)")
    save_json("fig5d_power", out)
    return out


if __name__ == "__main__":
    run()
