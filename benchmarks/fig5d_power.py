"""Fig. 5d: power breakdown across core units — analytical circuit model
next to the metered breakdown from a live ``analog_state`` training run
(``repro.telemetry``): the analog front-end (ADCs + Op-Amps) dominates
either way, and the two totals must agree within 5 %."""
from __future__ import annotations

import time

from repro.analog.costmodel import M2RUCostModel
from repro.backends import get_backend
from repro.core.continual import ReplaySpec, TrainerSpec, run_continual
from repro.core.miru import MiRUConfig
from repro.data.synthetic import make_permuted_tasks
from repro.telemetry import MeteredEnergy

from benchmarks.common import emit, save_json


def run() -> dict:
    m = M2RUCostModel()
    t0 = time.time()
    brk = m.power_breakdown_w()
    total = sum(brk.values())
    out = {"breakdown_mw": {k: v * 1e3 for k, v in brk.items()},
           "total_mw": total * 1e3,
           "training_mw": m.power_w(training=True) * 1e3,
           "shares": {k: v / total for k, v in brk.items()}}
    emit("fig5d/total", (time.time() - t0) * 1e6,
         f"total={total*1e3:.2f}mW(expect48.62)")
    for k, v in brk.items():
        emit(f"fig5d/{k}", 0.0, f"{v*1e3:.3f}mW({v/total*100:.1f}%)")

    # Metered reproduction: the same breakdown from live backend counters.
    t1 = time.time()
    tasks = make_permuted_tasks(0, n_tasks=2, n_train=96, n_test=32)
    backend = get_backend("analog_state")
    backend.telemetry.enable()
    run_continual(MiRUConfig(n_x=28, n_h=100, n_y=10),
                  TrainerSpec(algo="dfa", epochs_per_task=1), tasks,
                  replay=ReplaySpec(capacity=64), device=backend)
    rep = MeteredEnergy(m).analog_report(backend.telemetry.snapshot())
    metered_mw = {k: e / rep.time_s * 1e3
                  for k, e in rep.breakdown_j.items()}
    out["metered_breakdown_mw"] = metered_mw
    out["metered_total_mw"] = rep.power_w * 1e3
    out["metered_training_mw"] = rep.power_training_w * 1e3
    out["within_5pct"] = abs(rep.power_w - total) / total < 0.05
    emit("fig5d/metered", (time.time() - t1) * 1e6,
         f"total={rep.power_w*1e3:.2f}mW;"
         f"within_5pct={out['within_5pct']}")
    save_json("fig5d_power", out)
    return out


if __name__ == "__main__":
    run()
