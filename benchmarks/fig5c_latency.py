"""Fig. 5c: latency vs network scale and bit precision, with/without
tiling (analytical circuit model)."""
from __future__ import annotations

import dataclasses
import time

from repro.analog.costmodel import M2RUCostModel

from benchmarks.common import emit, save_json


def run() -> dict:
    base = M2RUCostModel()
    out = {}
    t0 = time.time()
    for tiled in (True, False):
        for n_h in (64, 100, 128, 256, 512):
            for n_bits in (2, 4, 8, 16):
                m = dataclasses.replace(base, n_h=n_h, n_bits=n_bits,
                                        tiled=tiled)
                out[f"tiled{int(tiled)}_nh{n_h}_b{n_bits}"] = {
                    "cycles": m.step_cycles(),
                    "latency_us": m.step_latency_s() * 1e6,
                }
    # Headline points from the paper.
    m = base
    out["paper_point"] = {"latency_us": m.step_latency_s() * 1e6,
                          "expect": 1.85}
    emit("fig5c/paper_point", (time.time() - t0) * 1e6,
         f"lat={m.step_latency_s()*1e6:.2f}us(expect1.85)")
    bits_share = (8 + 8) / m.step_cycles()
    emit("fig5c/bit_share_tiled", 0.0,
         f"bits_share={bits_share:.2f}(~1/3 per paper)")
    save_json("fig5c_latency", out)
    return out


if __name__ == "__main__":
    run()
