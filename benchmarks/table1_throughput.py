"""Table I: throughput / power / efficiency of the M2RU accelerator,
plus a timed software forward of the same 28×100×10 network for context
(the fused Pallas MiRU path, interpret mode on CPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.analog.costmodel import M2RUCostModel
from repro.core.miru import MiRUConfig, init_miru_params, miru_forward

from benchmarks.common import emit, save_json, time_call


def run() -> dict:
    m = M2RUCostModel()
    out = {
        "step_latency_us": m.step_latency_s() * 1e6,
        "seq_per_s": m.throughput_seq_per_s(28),
        "gops": m.gops(),
        "power_mw": m.power_w() * 1e3,
        "power_train_mw": m.power_w(training=True) * 1e3,
        "gops_per_w": m.gops_per_watt(),
        "pj_per_op": m.pj_per_op(),
        "gain_vs_digital": m.efficiency_gain_vs_digital(),
        "paper": {"latency_us": 1.85, "seq_per_s": 19305, "gops": 15,
                  "power_mw": 48.62, "gops_per_w": 312,
                  "pj_per_op": 3.21, "gain": 29},
    }
    emit("table1/latency", 0.0,
         f"{out['step_latency_us']:.2f}us(expect1.85)")
    emit("table1/throughput", 0.0,
         f"{out['seq_per_s']:.0f}seq/s(expect19305);"
         f"{out['gops']:.2f}GOPS(expect~15)")
    emit("table1/efficiency", 0.0,
         f"{out['gops_per_w']:.0f}GOPS/W(expect312);"
         f"{out['pj_per_op']:.2f}pJ/op(expect3.21);29x_vs_digital")

    # Software context: batched forward of the same network on CPU.
    cfg = MiRUConfig(n_x=28, n_h=100, n_y=10)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (64, 28, 28))
    fwd = jax.jit(lambda p, xx: miru_forward(p, cfg, xx)[0])
    us = time_call(lambda: fwd(params, x).block_until_ready())
    out["sw_fwd_us_batch64"] = us
    emit("table1/software_fwd", us, f"batch64_seq28_cpu")
    save_json("table1_throughput", out)
    return out


if __name__ == "__main__":
    run()
