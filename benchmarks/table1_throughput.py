"""Table I: throughput / power / efficiency of the M2RU accelerator —
now derived two independent ways and cross-checked:

  analytical  closed-form circuit model (``analog/costmodel.py``), and
  metered     ``repro.telemetry`` counters from a live continual-learning
              run on the ``analog_state`` backend (and a ``cmos`` run of
              the same workload for the 29× comparison), folded into
              watts/GOPS by the energy model.

The two must agree within 5 % (recorded as ``agreement``); a timed
software forward of the same 28×100×10 network is kept for context.

``--fast`` shrinks the metered workload for CI smoke runs and emits
``BENCH_table1.json`` in the working directory so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.analog.costmodel import M2RUCostModel
from repro.backends import get_backend
from repro.core.continual import ReplaySpec, TrainerSpec, run_continual
from repro.core.miru import MiRUConfig, init_miru_params, miru_forward
from repro.data.synthetic import make_permuted_tasks
from repro.telemetry import cmos_comparison, telemetry_report

from benchmarks.common import append_history, emit, save_json, time_call


def metered_run(backend_name: str, fast: bool) -> tuple:
    """Short continual-learning run on the paper shape with telemetry."""
    n_train = 96 if fast else 320
    tasks = make_permuted_tasks(0, n_tasks=2, n_train=n_train, n_test=32)
    cfg = MiRUConfig(n_x=28, n_h=100, n_y=10)
    backend = get_backend(backend_name,
                          spec_overrides=dict(track_endurance=True))
    backend.telemetry.enable()
    res = run_continual(
        cfg, TrainerSpec(algo="dfa", epochs_per_task=1 if fast else 2),
        tasks, replay=ReplaySpec(capacity=64), device=backend)
    return backend, res


def run(fast: bool = False) -> dict:
    m = M2RUCostModel()
    out = {
        "step_latency_us": m.step_latency_s() * 1e6,
        "seq_per_s": m.throughput_seq_per_s(28),
        "gops": m.gops(),
        "power_mw": m.power_w() * 1e3,
        "power_train_mw": m.power_w(training=True) * 1e3,
        "gops_per_w": m.gops_per_watt(),
        "pj_per_op": m.pj_per_op(),
        "gain_vs_digital": m.efficiency_gain_vs_digital(),
        "paper": {"latency_us": 1.85, "seq_per_s": 19305, "gops": 15,
                  "power_mw": 48.62, "gops_per_w": 312,
                  "pj_per_op": 3.21, "gain": 29},
    }
    emit("table1/latency", 0.0,
         f"{out['step_latency_us']:.2f}us(expect1.85)")
    emit("table1/throughput", 0.0,
         f"{out['seq_per_s']:.0f}seq/s(expect19305);"
         f"{out['gops']:.2f}GOPS(expect~15)")
    emit("table1/efficiency", 0.0,
         f"{out['gops_per_w']:.0f}GOPS/W(expect312);"
         f"{out['pj_per_op']:.2f}pJ/op(expect3.21);29x_vs_digital")

    # ------------------------------------------------------------------
    # Metered reproduction: live run → counters → watts/GOPS.
    # ------------------------------------------------------------------
    t0 = time.time()
    analog_backend, analog_res = metered_run("analog_state", fast)
    rep = telemetry_report(analog_backend.telemetry, model=m,
                           tracker=analog_res.get("endurance"))
    cmos_backend, _ = metered_run("cmos", fast)
    cmp = cmos_comparison(analog_backend.telemetry,
                          cmos_backend.telemetry, model=m)
    met = rep["metered"]
    out["metered"] = met
    out["metered"]["gain_vs_digital"] = cmp["efficiency_gain"]
    out["metered"]["cmos_pj_per_op"] = cmp["cmos_pj_per_op"]
    if "lifetime" in rep:
        out["lifetime"] = rep["lifetime"]
    out["agreement"] = {
        k: abs(met[k] - out[k]) / out[k]
        for k in ("power_mw", "gops", "gops_per_w", "pj_per_op",
                  "step_latency_us")}
    out["within_5pct"] = all(v < 0.05 for v in out["agreement"].values())
    emit("table1/metered", (time.time() - t0) * 1e6,
         f"{met['power_mw']:.2f}mW;{met['gops']:.2f}GOPS;"
         f"{met['gops_per_w']:.0f}GOPS/W;"
         f"gain={cmp['efficiency_gain']:.1f}x;"
         f"within_5pct={out['within_5pct']}")
    if "lifetime" in out:
        emit("table1/lifetime", 0.0,
             f"{out['lifetime']['years_mean']:.1f}years(expect~12.2)")

    # Software context: batched forward of the same network on CPU.
    cfg = MiRUConfig(n_x=28, n_h=100, n_y=10)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (64, 28, 28))
    fwd = jax.jit(lambda p, xx: miru_forward(p, cfg, xx)[0])
    us = time_call(lambda: fwd(params, x).block_until_ready())
    out["sw_fwd_us_batch64"] = us
    emit("table1/software_fwd", us, f"batch64_seq28_cpu")
    save_json("table1_throughput", out)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small metered workload; emit BENCH_table1.json")
    args = ap.parse_args()
    out = run(fast=args.fast)
    if args.fast:
        Path("BENCH_table1.json").write_text(
            json.dumps(out, indent=1, default=float))
        print("wrote BENCH_table1.json")
        append_history(
            "table1_throughput",
            {"power_mw": out["metered"]["power_mw"],
             "gops_per_w": out["metered"]["gops_per_w"],
             "pj_per_op": out["metered"]["pj_per_op"],
             "agreement": out["agreement"]},
            gates={"within_5pct": out["within_5pct"]})
    return 0 if out["within_5pct"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
