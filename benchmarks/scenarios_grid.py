"""Scenario × backend grid — the continual-learning sweep, compiled.

Runs the ``repro.scenarios`` suite through the compiled scan-over-tasks
sweep on each device substrate and emits ``BENCH_scenarios.json``:

  cells      avg accuracy / forgetting / BWT / FWT per scenario × backend,
             plus live-metered mW and GOPS/W on metered substrates
  speedup    compiled sweep vs the per-task Python loop, end-to-end
             wall-clock on the paper's 28×100×10 config (gate: ≥ 2×)
  parity     compiled R equals the loop's R bit-for-bit on
             permuted × ideal (tight tolerance: exact)

``--fast`` shrinks to a 2-scenario × 2-backend smoke grid for CI.
Exit status is nonzero when the parity or ≥2× speedup gate fails.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.continual import ReplaySpec, TrainerSpec, run_continual
from repro.scenarios import (build_scenario, run_compiled, run_sweep,
                             scenario_miru_config)

from benchmarks.common import emit, save_json

FAST_GRID = dict(scenarios=("permuted", "rotated"),
                 backends=("ideal", "analog_state"),
                 sizes=dict(n_tasks=3, n_train=192, n_test=96),
                 epochs=2, n_h=100)
FULL_GRID = dict(scenarios=("permuted", "split", "rotated", "noisy_label",
                            "drift", "class_incremental", "streaming"),
                 backends=("ideal", "wbs", "analog", "analog_state",
                           "cmos"),
                 sizes=dict(n_tasks=4, n_train=500, n_test=200),
                 epochs=4, n_h=100)


def measure_speedup(epochs: int = 3, n_tasks: int = 3, n_train: int = 640
                    ) -> dict:
    """Per-task Python loop vs compiled scan-over-tasks, same workload
    (28×100×10, ideal backend), end-to-end wall-clock including schedule
    building and compilation — the honest deployment comparison."""
    tasks = build_scenario("permuted", seed=0, n_tasks=n_tasks,
                           n_train=n_train, n_test=128)
    cfg = scenario_miru_config(tasks, n_h=100)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=epochs)
    rspec = ReplaySpec(capacity=512)

    t0 = time.perf_counter()
    loop = run_continual(cfg, trainer, tasks, replay=rspec, device="ideal")
    loop_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    comp = run_compiled(cfg, trainer, tasks, replay=rspec, device="ideal")
    compiled_s = time.perf_counter() - t0

    parity = bool(np.array_equal(loop["R"], comp["R"])
                  and loop["MA"] == comp["MA"])
    return {
        "config": {"n_x": 28, "n_h": 100, "n_y": 10, "n_tasks": n_tasks,
                   "n_train": n_train, "epochs": epochs,
                   "steps": n_tasks * comp["steps_per_task"]},
        "loop_s": loop_s,
        "compiled_s": compiled_s,
        "compiled_exec_s": comp["wall_s"],
        "speedup": loop_s / compiled_s,
        "parity_bitwise": parity,
        "MA": comp["MA"],
    }


def run(fast: bool = True) -> dict:
    p = FAST_GRID if fast else FULL_GRID
    t0 = time.time()
    grid = run_sweep(p["scenarios"], p["backends"],
                     TrainerSpec(algo="dfa", epochs_per_task=p["epochs"]),
                     ReplaySpec(capacity=512), n_h=p["n_h"],
                     scenario_kwargs=dict(p["sizes"]))
    for key, cell in grid["cells"].items():
        extra = (f";{cell['power_mw']:.1f}mW;"
                 f"{cell['gops_per_w']:.0f}GOPS/W"
                 if "power_mw" in cell else "")
        emit(f"scenarios/{key}", (cell.get("wall_s") or 0) * 1e6,
             f"MA={cell['MA']:.3f};"
             f"F={cell['metrics']['forgetting']:+.3f};"
             f"BWT={cell['metrics']['backward_transfer']:+.3f};"
             f"FWT={cell['metrics'].get('forward_transfer', 0):+.3f}"
             f"{extra}")
    grid["grid_seconds"] = time.time() - t0

    sp = measure_speedup()
    grid["speedup"] = sp
    emit("scenarios/compiled_speedup", sp["compiled_s"] * 1e6,
         f"{sp['speedup']:.2f}x_vs_loop({sp['loop_s']:.1f}s);"
         f"parity={sp['parity_bitwise']}")
    grid["gates"] = {"speedup_ge_2x": sp["speedup"] >= 2.0,
                     "parity_bitwise": sp["parity_bitwise"]}
    save_json("scenarios_grid", grid)
    return grid


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="2×2 smoke grid; emit BENCH_scenarios.json")
    ap.add_argument("--full", action="store_true",
                    help="full 7-scenario × 5-backend grid")
    args = ap.parse_args()
    out = run(fast=not args.full)
    Path("BENCH_scenarios.json").write_text(
        json.dumps(out, indent=1, default=float))
    print("wrote BENCH_scenarios.json")
    ok = all(out["gates"].values())
    if not ok:
        print(f"GATE FAILURE: {out['gates']}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
