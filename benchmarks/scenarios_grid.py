"""Scenario × backend grid — the continual-learning sweep, compiled.

Runs the ``repro.scenarios`` suite through the compiled scan-over-tasks
sweep on each device substrate and emits ``BENCH_scenarios.json``:

  cells      avg accuracy / forgetting / BWT / FWT per scenario × backend
             (each cell also records the resolved replay policy), plus
             live-metered mW and GOPS/W on metered substrates
  policies   per-policy ACC/forgetting columns for every registered
             repro.replay policy on the class-imbalanced
             class_incremental stream — the regime where the *choice*
             of rehearsal policy governs forgetting (gates:
             class_balanced beats reservoir; the reservoir schedule is
             bit-identical to the pre-policy-subsystem golden hash)
  speedup    compiled sweep vs the per-task Python loop, end-to-end
             wall-clock on the paper's 28×100×10 config (gate: ≥ 2×)
  parity     compiled R equals the loop's R bit-for-bit on
             permuted × ideal (tight tolerance: exact)

``--fast`` shrinks to a 2-scenario × 2-backend smoke grid for CI (the
policy columns and their gates run in both modes). Exit status is
nonzero when any gate fails.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.core.continual import (GOLDEN_PERMUTED_SCHEDULE_SHA256,
                                  ReplaySpec, TrainerSpec,
                                  build_batch_schedule, run_continual)
from repro.replay import available_policies
from repro.scenarios import (build_scenario, run_compiled, run_sweep,
                             scenario_miru_config)

from benchmarks.common import append_history, emit, save_json

# The policy-column workload: class-incremental with a 3× per-task
# stream growth (imbalance), where frequency-weighted rehearsal lets
# late classes flood the buffer — small capacity so policy choice bites.
POLICY_GRID = dict(scenario="class_incremental",
                   sizes=dict(n_tasks=4, n_train=48, n_test=96,
                              imbalance=3.0),
                   capacity=32, epochs=3, n_h=100, seeds=(0, 1, 2))

FAST_GRID = dict(scenarios=("permuted", "rotated"),
                 backends=("ideal", "analog_state"),
                 sizes=dict(n_tasks=3, n_train=192, n_test=96),
                 epochs=2, n_h=100)
FULL_GRID = dict(scenarios=("permuted", "split", "rotated", "noisy_label",
                            "drift", "class_incremental", "streaming"),
                 backends=("ideal", "wbs", "analog", "analog_state",
                           "cmos"),
                 sizes=dict(n_tasks=4, n_train=500, n_test=200),
                 epochs=4, n_h=100)


def measure_speedup(epochs: int = 3, n_tasks: int = 3, n_train: int = 640
                    ) -> dict:
    """Per-task Python loop vs compiled scan-over-tasks, same workload
    (28×100×10, ideal backend), end-to-end wall-clock including schedule
    building and compilation — the honest deployment comparison."""
    tasks = build_scenario("permuted", seed=0, n_tasks=n_tasks,
                           n_train=n_train, n_test=128)
    cfg = scenario_miru_config(tasks, n_h=100)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=epochs)
    rspec = ReplaySpec(capacity=512)

    t0 = time.perf_counter()
    loop = run_continual(cfg, trainer, tasks, replay=rspec, device="ideal")
    loop_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    comp = run_compiled(cfg, trainer, tasks, replay=rspec, device="ideal")
    compiled_s = time.perf_counter() - t0

    parity = bool(np.array_equal(loop["R"], comp["R"])
                  and loop["MA"] == comp["MA"])
    return {
        "config": {"n_x": 28, "n_h": 100, "n_y": 10, "n_tasks": n_tasks,
                   "n_train": n_train, "epochs": epochs,
                   "steps": n_tasks * comp["steps_per_task"]},
        "loop_s": loop_s,
        "compiled_s": compiled_s,
        "compiled_exec_s": comp["wall_s"],
        "speedup": loop_s / compiled_s,
        "parity_bitwise": parity,
        "MA": comp["MA"],
    }


def reservoir_schedule_digest() -> str:
    """sha256 of the permuted reference schedule under
    ReplaySpec(policy="reservoir") — must equal the pre-policy-subsystem
    golden (``GOLDEN_PERMUTED_SCHEDULE_SHA256``, the same constant the
    seed-determinism tests pin, here asserted through the *explicitly
    named* policy path)."""
    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=16)
    return build_batch_schedule(
        TrainerSpec(algo="dfa", epochs_per_task=1, seed=0),
        ReplaySpec(capacity=32, policy="reservoir"), tasks).digest()


def measure_policies() -> dict:
    """Per-policy forgetting/ACC columns on the imbalanced
    class-incremental stream (POLICY_GRID), every registered policy,
    seed-averaged. The stream is ragged (imbalance > 1), so each run
    takes the per-task loop — this column measures rehearsal quality,
    not compilation."""
    p = POLICY_GRID
    tasks = build_scenario(p["scenario"], seed=0, **p["sizes"])
    cfg = scenario_miru_config(tasks, n_h=p["n_h"])
    trainer = TrainerSpec(algo="adam", epochs_per_task=p["epochs"])
    columns: dict[str, dict] = {}
    for pol in available_policies():
        accs, fs = [], []
        for s in p["seeds"]:
            res = run_compiled(
                cfg, dataclasses.replace(trainer, seed=s), tasks,
                replay=ReplaySpec(capacity=p["capacity"], policy=pol),
                device="ideal", uniform=False)
            accs.append(res["metrics"]["average_accuracy"])
            fs.append(res["metrics"]["forgetting"])
        columns[pol] = {
            "ACC": float(np.mean(accs)),
            "ACC_std": float(np.std(accs)),
            "forgetting": float(np.mean(fs)),
            "forgetting_std": float(np.std(fs)),
        }
    return {"config": {**p, "seeds": list(p["seeds"]), "algo": "adam",
                       "task_sizes": [t.x_train.shape[0] for t in tasks]},
            "columns": columns}


def run(fast: bool = True) -> dict:
    p = FAST_GRID if fast else FULL_GRID
    t0 = time.time()
    grid = run_sweep(p["scenarios"], p["backends"],
                     TrainerSpec(algo="dfa", epochs_per_task=p["epochs"]),
                     ReplaySpec(capacity=512), n_h=p["n_h"],
                     scenario_kwargs=dict(p["sizes"]))
    for key, cell in grid["cells"].items():
        extra = (f";{cell['power_mw']:.1f}mW;"
                 f"{cell['gops_per_w']:.0f}GOPS/W"
                 if "power_mw" in cell else "")
        if "zeta_write_rate" in cell and cell["zeta_write_rate"]:
            z = cell["zeta_write_rate"]
            extra += (f";life={cell['lifetime_years']:.1f}y;"
                      f"zeta_p50={z['p50']:.3f};zeta_p99={z['p99']:.3f}")
        emit(f"scenarios/{key}", (cell.get("wall_s") or 0) * 1e6,
             f"MA={cell['MA']:.3f};"
             f"F={cell['metrics']['forgetting']:+.3f};"
             f"BWT={cell['metrics']['backward_transfer']:+.3f};"
             f"FWT={cell['metrics'].get('forward_transfer', 0):+.3f}"
             f"{extra}")
    grid["grid_seconds"] = time.time() - t0

    sp = measure_speedup()
    grid["speedup"] = sp
    emit("scenarios/compiled_speedup", sp["compiled_s"] * 1e6,
         f"{sp['speedup']:.2f}x_vs_loop({sp['loop_s']:.1f}s);"
         f"parity={sp['parity_bitwise']}")

    pol = measure_policies()
    grid["policies"] = pol
    for name, col in pol["columns"].items():
        emit(f"scenarios/policy/{name}", 0,
             f"ACC={col['ACC']:.3f};F={col['forgetting']:+.3f}")
    cols = pol["columns"]
    digest = reservoir_schedule_digest()
    grid["reservoir_schedule_sha256"] = digest

    grid["gates"] = {
        "speedup_ge_2x": sp["speedup"] >= 2.0,
        "parity_bitwise": sp["parity_bitwise"],
        # The policy subsystem must leave the default rehearsal stream
        # untouched bit-for-bit...
        "reservoir_schedule_golden":
            digest == GOLDEN_PERMUTED_SCHEDULE_SHA256,
        # ...while class-balanced replay measurably beats it where the
        # policy choice matters (imbalanced class-incremental).
        "class_balanced_beats_reservoir": (
            cols["class_balanced"]["forgetting"]
            < cols["reservoir"]["forgetting"] - 0.05
            and cols["class_balanced"]["ACC"]
            > cols["reservoir"]["ACC"]),
    }
    save_json("scenarios_grid", grid)
    return grid


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="2×2 smoke grid; emit BENCH_scenarios.json")
    ap.add_argument("--full", action="store_true",
                    help="full 7-scenario × 5-backend grid")
    args = ap.parse_args()
    out = run(fast=not args.full)
    Path("BENCH_scenarios.json").write_text(
        json.dumps(out, indent=1, default=float))
    print("wrote BENCH_scenarios.json")
    append_history(
        "scenarios_grid",
        {"speedup": out["speedup"]["speedup"],
         "compiled_s": out["speedup"]["compiled_s"],
         "grid_seconds": out["grid_seconds"]},
        gates=out["gates"])
    ok = all(out["gates"].values())
    if not ok:
        print(f"GATE FAILURE: {out['gates']}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
