"""Ragged data-pipeline benchmark (repro.data, docs/data.md).

The refactor's load-bearing promises, as gated claims written to
``BENCH_data.json`` (merged into ``BENCH_all.json`` by
``benchmarks.run --gate``):

  * **padded parity is bitwise** — attaching a :class:`PadPolicy` to an
    already-aligned stream builds the exact pre-refactor compiled
    program: R/losses/params *and* the metered telemetry counters are
    bit-identical to ``pad=None`` (gate ``padded_parity_bitwise``).
  * **ragged loop ≡ compiled** — a stream ragged in n_train, n_test,
    and per-example length runs through the one masked compiled program
    with R matrices exactly equal to the per-task Python loop, for both
    ``last_batch`` modes (gate ``ragged_loop_compiled``).
  * **seq-MNIST on hardware tracks the software baseline** — the
    sequential-MNIST stream (offline surrogate; checksum-verified real
    data when cached) trained on the quantized ``wbs`` substrate lands
    within 5 accuracy points of the ``ideal`` float baseline on the
    same reduced config (gate ``seq_mnist_acc_gap``).

Also reported ungated: masked-program wall/compile overhead vs the
unmasked program on the same aligned stream.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import append_history, emit, save_json

SEQ_MNIST = dict(n_tasks=3, n_train=192, n_test=96)


def _aligned_setup(fast: bool):
    from repro.core.continual import ReplaySpec, TrainerSpec
    from repro.scenarios import build_scenario, scenario_miru_config
    tasks = build_scenario("permuted", seed=0, n_tasks=2,
                           n_train=96 if fast else 192,
                           n_test=64 if fast else 96)
    cfg = scenario_miru_config(tasks, n_h=30)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=2)
    return cfg, trainer, ReplaySpec(capacity=64), tasks


def _ragged_tasks():
    from repro.data.synthetic import TaskData
    rng = np.random.default_rng(0)
    t_max, f = 12, 8
    tasks = []
    for tid, (ntr, nte) in enumerate([(64, 32), (48, 24), (40, 32)]):
        def draw(n):
            x = rng.uniform(0, 1, size=(n, t_max, f)).astype(np.float32)
            y = rng.integers(0, 4, size=n).astype(np.int32)
            L = rng.integers(t_max // 2, t_max + 1, size=n).astype(np.int32)
            for i in range(n):
                x[i, L[i]:] = 0.0
            return x, y, L
        xtr, ytr, ltr = draw(ntr)
        xte, yte, lte = draw(nte)
        tasks.append(TaskData(xtr, ytr, xte, yte, task_id=tid,
                              train_lengths=ltr, test_lengths=lte))
    return tasks


# ---------------------------------------------------------------------------
# Gate 1: pad-attached-but-aligned is the exact pre-refactor program
# ---------------------------------------------------------------------------

def bench_padded_parity(fast: bool) -> dict:
    """run_compiled(pad=PadPolicy()) vs run_compiled() on an aligned
    stream: bitwise R/losses/params and equal telemetry counters, on
    the metered wbs substrate so the counter comparison has teeth."""
    import jax
    from repro.backends import get_backend
    from repro.data.ragged import PadPolicy
    from repro.scenarios import run_compiled
    cfg, trainer, rspec, tasks = _aligned_setup(fast)

    def run(pad):
        be = get_backend("wbs")
        be.telemetry.enable()
        t0 = time.perf_counter()
        res = run_compiled(cfg, trainer, tasks, rspec, be, pad=pad)
        wall = time.perf_counter() - t0
        return res, be.telemetry.snapshot(), wall

    base, tele_base, wall_base = run(None)
    pad, tele_pad, wall_pad = run(PadPolicy(last_batch="drop"))
    arrays_ok = bool(
        np.array_equal(np.asarray(base["R_full"]), np.asarray(pad["R_full"]))
        and np.array_equal(np.asarray(base["losses"]),
                           np.asarray(pad["losses"]))
        and all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(base["params"]),
                                jax.tree.leaves(pad["params"]))))
    tele_ok = tele_base == tele_pad
    emit("data/padded_parity", wall_pad * 1e6,
         f"arrays={arrays_ok};telemetry={tele_ok}")
    return {"arrays_bitwise": arrays_ok, "telemetry_equal": tele_ok,
            "wall_s_unpadded": wall_base, "wall_s_padded": wall_pad,
            "counters": {k: int(v) for k, v in tele_base.items()}}


def bench_masked_overhead(fast: bool) -> dict:
    """Ungated context: what the masked program costs on a stream that
    did not need it (force=True vs the unmasked build, one compile +
    one execute each)."""
    from repro.backends import get_backend
    from repro.data.ragged import PadPolicy
    from repro.scenarios import run_compiled
    cfg, trainer, rspec, tasks = _aligned_setup(fast=True)
    walls = {}
    for name, pad in [("unmasked", None), ("masked", PadPolicy(force=True))]:
        t0 = time.perf_counter()
        res = run_compiled(cfg, trainer, tasks, rspec,
                           get_backend("ideal"), pad=pad)
        walls[name] = time.perf_counter() - t0
        assert res["compiled"]
    emit("data/masked_overhead", walls["masked"] * 1e6,
         f"unmasked{walls['unmasked']:.2f}s;masked{walls['masked']:.2f}s")
    return walls


# ---------------------------------------------------------------------------
# Gate 2: ragged stream, loop vs compiled
# ---------------------------------------------------------------------------

def bench_ragged_parity(fast: bool) -> dict:
    from repro.core.continual import ReplaySpec, TrainerSpec, run_continual
    from repro.data.ragged import PadPolicy
    from repro.scenarios import run_compiled, scenario_miru_config
    tasks = _ragged_tasks()
    cfg = scenario_miru_config(tasks, n_h=24)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=1, batch_size=16)
    rspec = ReplaySpec(capacity=48)
    out = {}
    for mode in ("pad", "drop"):
        pol = PadPolicy(last_batch=mode)
        comp = run_compiled(cfg, trainer, tasks, rspec, "ideal",
                            uniform=False, pad=pol)
        loop = run_continual(cfg, trainer, tasks, rspec, "ideal", pad=pol)
        r_ok = bool(np.array_equal(np.asarray(comp["R"]),
                                   np.asarray(loop["R"])))
        loss_ok = bool(np.allclose(comp["losses"], loop["losses"],
                                   rtol=2e-5, atol=1e-6))
        out[mode] = {"compiled": bool(comp["compiled"]),
                     "R_exact": r_ok, "losses_close": loss_ok,
                     "MA": float(comp["MA"])}
        emit(f"data/ragged_{mode}", 0.0, f"R={r_ok};loss={loss_ok}")
    return out


# ---------------------------------------------------------------------------
# Gate 3: seq-MNIST accuracy on hardware vs the software baseline
# ---------------------------------------------------------------------------

def bench_seq_mnist(fast: bool) -> dict:
    """The paper's §VI-A stream through the refactored pipeline:
    hardware-constrained training (wbs quantized MAC) within 5 points
    of the ideal float baseline at the same reduced budget. Pinned to
    the deterministic offline surrogate so the gate is reproducible on
    network-less CI and never spends the run downloading — the real
    checksum-verified stream rides the same code path."""
    from repro.core.continual import ReplaySpec, TrainerSpec
    from repro.scenarios import (build_scenario, get_scenario,
                                 run_compiled, scenario_miru_config)
    sc = get_scenario("seq_mnist")
    kw = dict(SEQ_MNIST, offline=True)
    if fast:
        kw.update(n_train=128, n_test=64)
    tasks = build_scenario("seq_mnist", seed=0, **kw)
    cfg = scenario_miru_config(tasks, n_h=40)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=2 if fast else 4)
    rspec = ReplaySpec(capacity=128)
    res = {}
    for name in ("ideal", "wbs"):
        r = run_compiled(cfg, trainer, tasks, rspec, name,
                         uniform=sc.uniform, pad=sc.pad)
        res[name] = {"MA": float(r["MA"]),
                     "forgetting": float(r["metrics"]["forgetting"]),
                     "compiled": bool(r["compiled"])}
        emit(f"data/seq_mnist_{name}", 0.0, f"MA{r['MA']:.3f}")
    gap = res["ideal"]["MA"] - res["wbs"]["MA"]
    return {**res, "acc_gap": float(gap)}


# ---------------------------------------------------------------------------

def run(fast: bool = False) -> dict:
    out: dict = {}
    out["padded_parity"] = bench_padded_parity(fast)
    out["masked_overhead"] = bench_masked_overhead(fast)
    out["ragged"] = bench_ragged_parity(fast)
    out["seq_mnist"] = bench_seq_mnist(fast)
    out["gates"] = {
        "padded_parity_bitwise": bool(
            out["padded_parity"]["arrays_bitwise"]
            and out["padded_parity"]["telemetry_equal"]),
        "ragged_loop_compiled": bool(all(
            m["compiled"] and m["R_exact"] and m["losses_close"]
            for m in out["ragged"].values())),
        "seq_mnist_acc_gap": bool(out["seq_mnist"]["acc_gap"] <= 0.05),
    }
    save_json("data_bench", out)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="write BENCH_data.json and exit nonzero when a "
                         "data-pipeline gate fails")
    ap.add_argument("--fast", action="store_true",
                    help="smaller streams / fewer epochs")
    args = ap.parse_args()
    out = run(fast=args.fast)
    if args.gate:
        Path("BENCH_data.json").write_text(
            json.dumps(out, indent=1, default=float))
        print("wrote BENCH_data.json")
        append_history(
            "data_bench",
            {"seq_mnist_ideal_MA": out["seq_mnist"]["ideal"]["MA"],
             "seq_mnist_wbs_MA": out["seq_mnist"]["wbs"]["MA"],
             "seq_mnist_acc_gap": out["seq_mnist"]["acc_gap"],
             "masked_wall_s": out["masked_overhead"]["masked"],
             "unmasked_wall_s": out["masked_overhead"]["unmasked"]},
            gates=out["gates"])
        ok = all(out["gates"].values())
        if not ok:
            print(f"GATE FAILURE: {out['gates']}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
