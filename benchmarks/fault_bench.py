"""Device-fault degradation and mitigation benchmark (repro.faults).

What the M2RU network computes when crossbar devices *fail*, and how
much of it the mitigation stack claws back. Four gated claims, written
to ``BENCH_faults.json`` (merged into ``BENCH_all.json`` by
``benchmarks.run --gate``):

  * **zero-fault parity is bitwise** — a zero-rate :class:`FaultSpec`
    changes no bit of a full ``run_compiled`` training run against
    ``DeviceSpec.faults=None`` (gate ``zero_fault_parity_bitwise``).
  * **fused ≡ per-step under faults** — the fused WBS×MiRU recurrence
    and the per-step ``device_vmm`` scan read the same masked weight
    tensor, bitwise (gate ``fused_per_step_parity_under_faults``).
  * **mitigation recovers ≥ half the damage at 1 % stuck cells** —
    march self-test → redundant-column remap → bias compensation →
    recalibration recovers at least half of the accuracy the
    unmitigated faulty model lost, averaged over mask seeds (gate
    ``mitigation_recovers_half_at_1pct``).
  * **wear-out onset lands in the lifetime band** — with per-cell
    endurance limits active, the virtual device age at which half the
    cells are worn out falls within [0.5, 1.5]× the analytic
    ``lifespan_years`` projection for the measured write rate — the
    empirical half of the paper's 12.2-year claim (gate
    ``wearout_onset_in_lifetime_band``).

Also reported ungated: the accuracy-vs-stuck-rate degradation curve
(eval-only damage on a cleanly trained model) and the full wear-out
accuracy/stuck-fraction-vs-age trajectory.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import numpy as np

from benchmarks.common import append_history, emit, save_json

#: Stuck-cell rates for the degradation curve (total; half SA0, half
#: SA1 — SA1 cells read full range with random sign, the damaging end).
RATES = (0.0, 0.005, 0.01, 0.02, 0.05)
#: Mask seeds averaged for the degradation / mitigation figures.
MASK_SEEDS = (0, 1, 2)
WBS = dict(input_bits=8, adc_bits=8, weight_clip=1.0)


def _setup(fast: bool):
    from repro.core.continual import TrainerSpec
    from repro.scenarios import build_scenario
    from repro.scenarios.sweep import scenario_miru_config
    tasks = build_scenario("permuted", seed=0, n_tasks=2,
                           n_train=128 if fast else 256,
                           n_test=96 if fast else 192)
    cfg = scenario_miru_config(tasks, n_h=30)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=2)
    return cfg, trainer, tasks


def _easy_setup(fast: bool):
    """A prototype-sequence stream the smoke-sized MiRU actually masters
    (the permuted smoke scenario sits near chance at this budget, which
    makes accuracy_lost ≈ 0 and the mitigation gate meaningless). Each
    class is a fixed prototype row repeated over time with small noise;
    DFA reaches well above chance in a few epochs, so stuck cells cause
    a real, recoverable accuracy drop."""
    import numpy as np
    from repro.core.continual import TrainerSpec
    from repro.data.synthetic import TaskData
    from repro.scenarios.sweep import scenario_miru_config
    rng = np.random.default_rng(0)
    n_classes, F, T = 8, 16, 8
    n_train, n_test = (192, 96) if fast else (256, 128)
    tasks = []
    for t in range(2):
        protos = rng.uniform(0.1, 0.9,
                             size=(n_classes, F)).astype(np.float32)

        def draw(n):
            y = rng.integers(0, n_classes, size=n)
            x = protos[y][:, None, :] + 0.02 * rng.standard_normal(
                (n, T, F)).astype(np.float32)
            return np.clip(x, 0, 1).astype(np.float32), y.astype(np.int32)

        x_tr, y_tr = draw(n_train)
        x_te, y_te = draw(n_test)
        tasks.append(TaskData(x_tr, y_tr, x_te, y_te, task_id=t))
    cfg = scenario_miru_config(tasks, n_h=30)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=6)
    return cfg, trainer, tasks


def _backend(faults=None):
    from repro.backends import DeviceSpec, get_backend
    return get_backend("wbs", spec=DeviceSpec(**WBS, faults=faults))


def _spec(rate: float, spares: int = 0, **kw):
    from repro.faults import FaultSpec
    return FaultSpec(sa0_rate=rate / 2, sa1_rate=rate / 2,
                     n_spare_cols=spares, **kw)


def _evaluate(cfg, trainer, backend, params, state, tasks) -> float:
    """Mean test accuracy over tasks through ``backend`` with ``state``
    (fault masks included) — the deployed faulty forward."""
    import jax
    from repro.core.continual import _make_raw_steps
    _, evaluate, _ = _make_raw_steps(cfg, trainer, backend)
    accs = [float(evaluate(params, jax.random.PRNGKey(99),
                           t.x_test, t.y_test, state))
            for t in tasks]
    return float(np.mean(accs))


# ---------------------------------------------------------------------------
# Parity gates
# ---------------------------------------------------------------------------

def bench_parity(fast: bool) -> dict:
    """Zero-fault bitwise parity through run_compiled, and fused vs
    per-step bitwise parity under live masks."""
    import jax
    from repro.core.continual import ReplaySpec
    from repro.core.miru import init_miru_params
    from repro.faults import FaultSpec
    from repro.scenarios import run_compiled
    cfg, trainer, tasks = _setup(fast=True)
    kw = dict(replay=ReplaySpec(capacity=64))
    r0 = run_compiled(cfg, trainer, tasks, device=_backend(), **kw)
    r1 = run_compiled(cfg, trainer, tasks, device=_backend(FaultSpec()),
                      **kw)
    zero_ok = bool(
        np.array_equal(r0["R_full"], r1["R_full"])
        and all(np.array_equal(np.asarray(v),
                               np.asarray(r1["params"][k]))
                for k, v in r0["params"].items()))

    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    be = _backend(_spec(0.02))
    st = be.init_device_state(params, jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.n_x))
    outs = [np.asarray(be.device_recurrence(
        params, cfg, x, jax.random.PRNGKey(3), state=st, fused=f)[0])
        for f in (None, False)]
    fused_ok = bool(np.array_equal(outs[0], outs[1]))
    emit("faults/parity", 0.0, f"zero={zero_ok};fused={fused_ok}")
    return {"zero_fault_bitwise": zero_ok,
            "fused_per_step_bitwise": fused_ok}


# ---------------------------------------------------------------------------
# Degradation curve + mitigation
# ---------------------------------------------------------------------------

def bench_degradation(fast: bool) -> dict:
    """Accuracy vs stuck-cell rate on a cleanly trained model, averaged
    over mask seeds, plus the full mitigation stack at 1 % stuck."""
    import jax
    from repro.core.continual import ReplaySpec
    from repro.faults import (calibration_drives, compensate_bias,
                              effective_masks, march_recover, recalibrate,
                              remap_columns, stuck_fraction)
    from repro.scenarios import run_compiled
    cfg, trainer, tasks = _easy_setup(fast)
    trained = run_compiled(cfg, trainer, tasks,
                           replay=ReplaySpec(capacity=64),
                           device=_backend())
    params = {k: np.asarray(v) for k, v in trained["params"].items()}

    curve = []
    for rate in RATES:
        accs, fracs = [], []
        for seed in MASK_SEEDS:
            be = _backend(_spec(rate))
            st = be.init_device_state(params, jax.random.PRNGKey(seed))
            accs.append(_evaluate(cfg, trainer, be, params, st, tasks))
            fracs.append(stuck_fraction(st["_faults"]) if st else 0.0)
            if rate == 0.0:
                break                     # seed-independent
        curve.append({"rate": rate,
                      "accuracy": float(np.mean(accs)),
                      "accuracy_per_seed": accs,
                      "stuck_fraction": float(np.mean(fracs))})
        emit(f"faults/degradation_{rate}", 0.0,
             f"acc{np.mean(accs):.3f};stuck{np.mean(fracs):.3f}")
    acc_clean = curve[0]["accuracy"]

    # Mitigation at 1 % stuck: march → remap → compensate → recalibrate.
    mit_seeds = []
    for seed in MASK_SEEDS:
        be = _backend(_spec(0.01, spares=4))
        st = be.init_device_state(params, jax.random.PRNGKey(seed))
        a_faulty = _evaluate(cfg, trainer, be, params, st, tasks)
        rec = march_recover(be, params, st)
        march_exact = all(
            np.array_equal(np.asarray(rec[n]["stuck"]),
                           np.asarray(effective_masks(t)[0]))
            for n, t in st["_faults"].items())
        st = dict(st)
        st["_faults"] = remap_columns(st["_faults"])
        x_cal = np.stack([t.x_train[:32] for t in tasks]).astype(np.float32)
        drives = calibration_drives(be, params, cfg,
                                    x_cal.reshape(-1, *x_cal.shape[2:]),
                                    jax.random.PRNGKey(11), state=st)
        p_m = compensate_bias(params, st["_faults"], drives)
        p_m, st = recalibrate(cfg, trainer, be, p_m, st, tasks[0],
                              steps=8 if fast else 16, seed=seed)
        a_mitig = _evaluate(cfg, trainer, be, p_m, st, tasks)
        mit_seeds.append({"seed": seed, "faulty": a_faulty,
                          "mitigated": a_mitig,
                          "march_exact": bool(march_exact)})
    a_f = float(np.mean([m["faulty"] for m in mit_seeds]))
    a_m = float(np.mean([m["mitigated"] for m in mit_seeds]))
    lost = acc_clean - a_f
    recovered = a_m - a_f
    emit("faults/mitigation", 0.0,
         f"clean{acc_clean:.3f};faulty{a_f:.3f};mitigated{a_m:.3f}")
    return {"curve": curve,
            "clean_accuracy": acc_clean,
            "mitigation": {"rate": 0.01, "spares": 4,
                           "per_seed": mit_seeds,
                           "faulty_accuracy": a_f,
                           "mitigated_accuracy": a_m,
                           "accuracy_lost": lost,
                           "accuracy_recovered": recovered,
                           "march_exact": all(m["march_exact"]
                                              for m in mit_seeds)}}


# ---------------------------------------------------------------------------
# Wear-out vs the analytic lifetime projection
# ---------------------------------------------------------------------------

def bench_wearout(fast: bool, update_period_s: float = 1e-3) -> dict:
    """Train with per-cell endurance limits active and record the
    accuracy / stuck-fraction trajectory against *virtual device age*
    (``n_updates × wearout_scale × update_period_s``). The age at which
    half the cells are worn is compared with ``lifespan_years`` for the
    measured mean write rate — the acceleration factor cancels, so a
    tiny endurance sweeps a multi-year virtual age in seconds."""
    import jax
    import jax.numpy as jnp
    from repro.analog.endurance import lifespan_years
    from repro.core.continual import _init_run, _make_raw_steps
    from repro.faults import stuck_fraction
    cfg, trainer, tasks = _setup(fast=True)
    # Paper-scale endurance; the acceleration factor compresses the
    # projected lifetime into a few dozen training updates. The analytic
    # projection and the virtual-age clock share update_period_s, so the
    # factor cancels: a cell written at the mean rate wears out at
    # exactly the age lifespan_years projects for that rate.
    endurance = 1e9
    scale = endurance / 30.0
    fs = dataclasses.replace(
        _spec(0.0), wearout=True, wearout_endurance=endurance,
        wearout_spread=0.3, wearout_scale=scale)
    be = _backend(fs)
    train_step, evaluate, _ = _make_raw_steps(cfg, trainer, be)
    key, params, psi, _ = _init_run(cfg, trainer, be)
    state = be.init_device_state(params, jax.random.PRNGKey(0))
    opt_state = {"psi": psi}
    task = tasks[0]
    n = task.x_train.shape[0]
    B = min(trainer.batch_size, 32)
    max_updates, eval_every = (100, 10) if fast else (150, 10)
    write_rates, stuck_series, traj = [], [], []
    year_per_update = scale * update_period_s / (365.25 * 24 * 3600)
    for step in range(max_updates):
        key, k_step, k_batch = jax.random.split(key, 3)
        idx = np.asarray(jax.random.choice(k_batch, n, (B,),
                                           replace=False))
        params, opt_state, _, applied, state = train_step(
            params, opt_state, k_step,
            jnp.asarray(task.x_train[idx]), jnp.asarray(task.y_train[idx]),
            state)
        if step < 5:                  # before anything wears out
            write_rates.append(float(np.mean([
                np.mean(np.asarray(a) != 0)
                for a in jax.device_get(applied).values()])))
        # Per-update stuck fraction: onset detection needs finer
        # resolution than the accuracy cadence.
        frac = stuck_fraction(state["_faults"])
        stuck_series.append(
            {"virtual_age_years": (step + 1) * year_per_update,
             "stuck_fraction": frac})
        if step % eval_every == 0 or frac > 0.95:
            acc = float(evaluate(params, jax.random.PRNGKey(7),
                                 task.x_test, task.y_test, state))
            traj.append({"update": step + 1,
                         "virtual_age_years":
                             (step + 1) * year_per_update,
                         "stuck_fraction": frac, "accuracy": acc})
        if frac > 0.95:
            break
    zeta = float(np.mean(write_rates))
    proj_years = lifespan_years(zeta, endurance=endurance,
                                update_period_s=update_period_s)
    onset = next((t["virtual_age_years"] for t in stuck_series
                  if t["stuck_fraction"] >= 0.5), None)
    ratio = onset / proj_years if onset else None
    emit("faults/wearout", 0.0,
         f"proj{proj_years:.1f}y;onset{onset or -1:.1f}y")
    return {"endurance_writes": endurance, "wearout_scale": scale,
            "update_period_s": update_period_s,
            "mean_write_rate": zeta,
            "projected_lifespan_years": proj_years,
            "onset_age_years": onset,
            "onset_over_projection": ratio,
            "trajectory": traj,
            "final_accuracy": traj[-1]["accuracy"],
            "initial_accuracy": traj[0]["accuracy"]}


# ---------------------------------------------------------------------------

def run(fast: bool = False) -> dict:
    out: dict = {"rates": list(RATES), "mask_seeds": list(MASK_SEEDS)}
    out["parity"] = bench_parity(fast)
    out["degradation"] = bench_degradation(fast)
    out["wearout"] = bench_wearout(fast)
    mit = out["degradation"]["mitigation"]
    ratio = out["wearout"]["onset_over_projection"]
    out["gates"] = {
        "zero_fault_parity_bitwise":
            out["parity"]["zero_fault_bitwise"],
        "fused_per_step_parity_under_faults":
            out["parity"]["fused_per_step_bitwise"],
        "mitigation_recovers_half_at_1pct": bool(
            mit["accuracy_lost"] > 0
            and mit["accuracy_recovered"] >= 0.5 * mit["accuracy_lost"]),
        "wearout_onset_in_lifetime_band": bool(
            ratio is not None and 0.5 <= ratio <= 1.5),
    }
    save_json("fault_bench", out)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="write BENCH_faults.json and exit nonzero when "
                         "a fault gate fails")
    ap.add_argument("--fast", action="store_true",
                    help="smaller scenario / fewer recalibration steps")
    args = ap.parse_args()
    out = run(fast=args.fast)
    if args.gate:
        Path("BENCH_faults.json").write_text(
            json.dumps(out, indent=1, default=float))
        print("wrote BENCH_faults.json")
        mit = out["degradation"]["mitigation"]
        append_history(
            "fault_bench",
            {"clean_accuracy": out["degradation"]["clean_accuracy"],
             "faulty_1pct": mit["faulty_accuracy"],
             "mitigated_1pct": mit["mitigated_accuracy"],
             "wearout_onset_years": out["wearout"]["onset_age_years"],
             "wearout_projected_years":
                 out["wearout"]["projected_lifespan_years"]},
            gates=out["gates"])
        ok = all(out["gates"].values())
        if not ok:
            print(f"GATE FAILURE: {out['gates']}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
