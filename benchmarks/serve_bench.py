"""Continuous-batching recurrent serving under synthetic load.

The deployment-shape benchmark of ROADMAP item 2: the paper's 28×100×10
MiRU served as many short stateful user streams through
``repro.serve.RecurrentServeEngine`` (state slab + LRU spill + fused
``device_recurrence`` on the wbs substrate). Four gated claims, written
to ``BENCH_serve.json`` (merged into ``BENCH_all.json`` by
``benchmarks.run --gate``):

  * **continuous batching scales** — a 64-request burst served at 64
    concurrent streams completes ≥ 3× the sequences/s of the same
    traffic through a single-stream engine (gate ``throughput_3x_at_64``).
  * **latency stays bounded under Poisson load** — arrivals at ~50 % of
    the measured 64-stream capacity keep p99 end-to-end latency under a
    generous CI ceiling (gate ``p99_under_ceiling``; the p50/p99/
    queue-wait/decode split is reported either way).
  * **batch composition is bitwise-inert** — every request of a mixed
    returning-user trace (slot churn, eviction + reload, co-batching)
    reproduces its solo-serve stream exactly (gate
    ``bitwise_invariance`` — the determinism contract, docs/serving.md).
  * **the model zoo reports serving energy** — LM smoke configs served
    on the metered wbs substrate produce finite GOPS/W, mW and
    pJ/request through the transformer-shape
    ``DenseCostModel`` (gate ``zoo_energy_finite``).

Timings are CPU wall-clock — context for the derived ratios, not a chip
claim; the metered energy numbers come from the activity counters and
are machine-independent.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import append_history, emit, save_json

# Paper geometry: 28 features × 100 hidden × 10 classes, n_T = 28.
N_X, N_H, N_Y = 28, 100, 10
CONCURRENT = 64          # the gate's concurrent-stream count
CHUNK = 14               # frames per stream per engine step
#: LM smoke configs for the zoo serving-energy table — one per serving-
#: relevant family (dense GQA / MoE / SSM). Encoder-decoder configs are
#: not servable through the decode engine and are excluded.
ZOO = ["qwen2-0.5b", "granite-moe-3b-a800m", "mamba2-370m"]


def _miru():
    import jax
    from repro.core.miru import MiRUConfig, init_miru_params
    cfg = MiRUConfig(n_x=N_X, n_h=N_H, n_y=N_Y)
    return cfg, init_miru_params(jax.random.PRNGKey(0), cfg)


def _engine(cfg, params, **kw):
    from repro.serve import RecurrentServeConfig, RecurrentServeEngine
    kw.setdefault("device", "wbs")
    kw.setdefault("fresh_meter", True)
    return RecurrentServeEngine(cfg, RecurrentServeConfig(**kw), params)


def _burst_spec(n_requests: int, frames: int, seed: int = 0):
    from repro.serve import TrafficSpec
    return TrafficSpec(n_requests=n_requests, rate_hz=None,
                       frames_min=frames, frames_max=frames,
                       n_x=N_X, seed=seed)


def _serve_burst(cfg, params, spec, batch_slots: int, **kw) -> dict:
    """Submit the whole trace at t=0, drain, return timing + stats.
    A full-occupancy warm-up round is served first so jit compilation
    of the measured (S=batch_slots) step shape stays out of the
    measured window."""
    from repro.serve import replay, request_frames
    eng = _engine(cfg, params, batch_slots=batch_slots, chunk=CHUNK, **kw)
    for i in range(batch_slots):
        eng.submit(request_frames(spec, rid=10_000 + i,
                                  n_frames=spec.frames_max),
                   uid=f"_warm{i}")
    eng.run_until_drained()
    # Prime the spill/reload row helpers too — the measured run churns
    # the fully-resident slab, the warm-up round above never does.
    eng.slab.evict("_warm0")
    eng.slab.acquire("_warm0")
    reqs = [eng.submit(f, uid=f"u{a.rid}") for a, f in replay(spec)]
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    stats = eng.request_stats()
    return {"wall_s": wall,
            "sequences_per_s": len(reqs) / wall,
            "frames_per_s": sum(r.emitted for r in reqs) / wall,
            "latency_ms": stats["latency_ms"],
            "slab": stats["slab"],
            "energy": stats.get("energy"),
            "engine_steps": stats["steps_run"]}


def bench_throughput(frames: int) -> dict:
    """64-request burst: single-stream baseline vs 64 concurrent
    streams, same traffic, same chunking.

    Runs on both substrates — the analog ``wbs`` emulation (the
    serving target, gated) and the digital ``cmos`` baseline (engine
    mechanics under plain XLA, reported). The warm-up round primes
    every compiled shape the measured window hits, including the
    slab's spill/reload row helpers."""
    cfg, params = _miru()
    spec = _burst_spec(CONCURRENT, frames)
    out: dict = {"config": {"n_x": N_X, "n_h": N_H, "n_y": N_Y,
                            "frames": frames, "chunk": CHUNK,
                            "concurrent": CONCURRENT}}
    for dev in ("cmos", "wbs"):
        base = _serve_burst(cfg, params, spec, batch_slots=1, device=dev)
        loaded = _serve_burst(cfg, params, spec, batch_slots=CONCURRENT,
                              device=dev)
        speedup = loaded["sequences_per_s"] / base["sequences_per_s"]
        emit(f"serve/throughput_{dev}_1", base["wall_s"] * 1e6,
             f"{base['sequences_per_s']:.0f}seq_s")
        emit(f"serve/throughput_{dev}_64", loaded["wall_s"] * 1e6,
             f"{loaded['sequences_per_s']:.0f}seq_s;x{speedup:.1f}")
        out[dev] = {"baseline_1": base, "loaded_64": loaded,
                    "speedup": speedup}
    out["speedup"] = out["wbs"]["speedup"]           # the gated figure
    return out


def bench_poisson(frames: int, capacity_seq_s: float,
                  n_requests: int = 48) -> dict:
    """Deterministic Poisson arrivals at ~50 % of the measured cmos
    capacity, submitted in real time against the wall clock; reports
    the end-to-end / queue-wait / decode latency split."""
    from repro.serve import TrafficSpec, make_arrivals, request_frames
    cfg, params = _miru()
    rate = max(1.0, 0.5 * capacity_seq_s)
    spec = TrafficSpec(n_requests=n_requests, rate_hz=rate,
                       n_users=n_requests // 3, frames_min=frames // 2,
                       frames_max=frames, n_x=N_X, seed=1)
    eng = _engine(cfg, params, batch_slots=8, chunk=CHUNK, device="cmos")
    for i in range(8):                      # warm the full-occupancy shape
        eng.submit(request_frames(spec, rid=10_000 + i, n_frames=frames),
                   uid=f"_warm{i}")
    eng.run_until_drained()
    arrivals = make_arrivals(spec)
    reqs, i = [], 0
    t0 = time.perf_counter()
    while i < len(arrivals) or eng.pending:
        now = time.perf_counter() - t0
        if i < len(arrivals) and arrivals[i].t <= now:
            a = arrivals[i]
            reqs.append(eng.submit(request_frames(spec, a.rid, a.n_frames),
                                   uid=a.uid))
            i += 1
            continue
        if eng.step() == 0 and not eng.pending and i < len(arrivals):
            time.sleep(min(1e-3, max(0.0, arrivals[i].t - now)))
    assert all(r.done for r in reqs)
    stats = eng.request_stats()
    emit("serve/poisson_p99", stats["latency_ms"]["p99"] * 1e3,
         f"rate{rate:.0f}hz;p50_{stats['latency_ms']['p50']:.2f}ms")
    return {"rate_hz": rate, "n_requests": n_requests,
            "latency_ms": stats["latency_ms"],
            "queue_wait_ms": stats["queue_wait_ms"],
            "decode_ms": stats["decode_ms"],
            "sequences_per_s": stats["sequences_per_s"],
            "slab": stats["slab"]}


def bench_invariance() -> dict:
    """Solo-serve goldens vs a co-batched mixed trace with returning
    users (forced spill/reload on a 4-slot slab)."""
    from repro.serve import TrafficSpec, make_arrivals, replay
    cfg, params = _miru()
    spec = TrafficSpec(n_requests=24, n_users=10, frames_min=8,
                       frames_max=28, n_x=N_X, seed=42)
    golden: dict[int, np.ndarray] = {}
    solo: dict = {}
    for a, frames in replay(spec):
        eng = solo.get(a.uid)
        if eng is None:
            eng = solo[a.uid] = _engine(cfg, params, batch_slots=1,
                                        chunk=28)
        req = eng.submit(frames, uid=a.uid)
        eng.run_until_drained()
        golden[a.rid] = np.asarray(req.logits)
    eng = _engine(cfg, params, batch_slots=4, chunk=7)
    reqs = [eng.submit(f, uid=a.uid) for a, f in replay(spec)]
    eng.run_until_drained()
    mismatched = [a.rid for a, r in zip(make_arrivals(spec), reqs)
                  if not np.array_equal(np.asarray(r.logits),
                                        golden[a.rid])]
    st = eng.slab.stats()
    emit("serve/invariance", 0.0,
         f"mismatched={len(mismatched)};evictions={st['evictions']}")
    return {"n_requests": spec.n_requests, "n_users": spec.n_users,
            "evictions": st["evictions"], "reloads": st["reloads"],
            "mismatched_rids": mismatched,
            "bitwise": not mismatched and st["evictions"] > 0}


def bench_energy(frames: int) -> dict:
    """Metered serving power for the M2RU geometry: a 64-stream burst on
    a fresh metered wbs instance → mW / pJ/request / GOPS/W from the
    activity counters (machine-independent)."""
    cfg, params = _miru()
    spec = _burst_spec(CONCURRENT, frames, seed=2)
    res = _serve_burst(cfg, params, spec, batch_slots=CONCURRENT,
                       meter=True)
    en = res["energy"]
    emit("serve/power", 0.0,
         f"{en['power_mw']:.1f}mW;{en['pj_per_request']['p50']:.0f}"
         f"pJ_req_p50")
    return {"power_mw": en["power_mw"], "total_j": en["total_j"],
            "gops_per_w": en["gops_per_w"], "pj_per_op": en["pj_per_op"],
            "pj_per_request": en["pj_per_request"]}


def bench_zoo() -> dict:
    """Model-zoo serving energy via the transformer-shape DenseCostModel:
    each LM smoke config serves a small metered batch on wbs and reports
    GOPS/W + pJ/request. The zoo engines share the per-name inference
    backend, so counters are reset per config."""
    import jax
    from repro.backends import inference_backend
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serve import ServeConfig, ServeEngine
    backend = inference_backend("wbs")
    out: dict = {}
    for name in ZOO:
        cfg = get_smoke_config(name)
        backend.telemetry.reset()
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, ServeConfig(batch_slots=2, max_len=24,
                                           eos_token=-1, device="wbs",
                                           meter=True), params)
        for r in range(3):
            eng.submit([1 + r, 2, 3], max_new=4)
        eng.run_until_drained()
        stats = eng.request_stats()          # default: DenseCostModel
        en = stats["energy"]
        out[name] = {"family": cfg.family,
                     "gops_per_w": en["gops_per_w"],
                     "power_mw": en["power_mw"],
                     "pj_per_op": en["pj_per_op"],
                     "pj_per_request_p50": en["pj_per_request"]["p50"],
                     "tokens_per_s": stats["tokens_per_s"]}
        emit(f"serve/zoo_{name}", 0.0,
             f"{en['gops_per_w']:.1f}gops_w;{en['pj_per_op']:.0f}pj_op")
        backend.telemetry.reset()
    backend.telemetry.disable()
    return out


def run(fast: bool = False, ceiling_ms: float = 2000.0) -> dict:
    frames = 14 if fast else 28
    out: dict = {}
    out["throughput"] = bench_throughput(frames)
    out["poisson"] = bench_poisson(
        frames, out["throughput"]["cmos"]["loaded_64"]["sequences_per_s"],
        n_requests=24 if fast else 48)
    out["invariance"] = bench_invariance()
    out["energy"] = bench_energy(frames)
    out["zoo"] = bench_zoo()
    zoo_ok = all(np.isfinite(v["gops_per_w"]) and v["gops_per_w"] > 0
                 and v["pj_per_request_p50"] > 0
                 for v in out["zoo"].values())
    out["gates"] = {
        "throughput_3x_at_64": out["throughput"]["speedup"] >= 3.0,
        "p99_under_ceiling":
            out["poisson"]["latency_ms"]["p99"] <= ceiling_ms,
        "bitwise_invariance": out["invariance"]["bitwise"],
        "zoo_energy_finite": bool(zoo_ok),
    }
    save_json("serve_bench", out)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="write BENCH_serve.json and exit nonzero when a "
                         "serving gate fails")
    ap.add_argument("--fast", action="store_true",
                    help="shorter streams / fewer Poisson requests")
    ap.add_argument("--ceiling-ms", type=float, default=2000.0,
                    help="p99 end-to-end latency gate ceiling (CI-safe "
                         "default; the report carries the real numbers)")
    args = ap.parse_args()
    out = run(fast=args.fast, ceiling_ms=args.ceiling_ms)
    if args.gate:
        Path("BENCH_serve.json").write_text(
            json.dumps(out, indent=1, default=float))
        print("wrote BENCH_serve.json")
        append_history(
            "serve_bench",
            {"speedup": out["throughput"]["speedup"],
             "seq_per_s_64": out["throughput"]["wbs"]["loaded_64"]
             ["sequences_per_s"],
             "poisson_p99_ms": out["poisson"]["latency_ms"]["p99"],
             "power_mw": out["energy"]["power_mw"]},
            gates=out["gates"])
        ok = all(out["gates"].values())
        if not ok:
            print(f"GATE FAILURE: {out['gates']}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
