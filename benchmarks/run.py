"""Run every benchmark. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    # benchmarks.scenarios_grid is not in this list: it runs (gated, with
    # its BENCH_scenarios.json artifact) in its own CI job.
    from benchmarks import (fig4_continual, fig5a_quant_error,
                            fig5b_endurance, fig5c_latency, fig5d_power,
                            kernel_bench, roofline_bench,
                            table1_throughput)
    t0 = time.time()
    print("name,us_per_call,derived")
    table1_throughput.run(fast=True)
    fig5c_latency.run()
    fig5d_power.run()
    fig5a_quant_error.run()
    fig5b_endurance.run()
    kernel_bench.run()
    fig4_continual.run(fast=True)
    roofline_bench.run()
    print(f"# total_bench_seconds={time.time() - t0:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()
