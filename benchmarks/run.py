"""Run every benchmark. One function per paper table/figure.

Two modes:

* ``python -m benchmarks.run`` — the legacy smoke sweep: every figure
  benchmark in-process, printing ``name,us_per_call,derived`` CSV rows
  (benchmarks.common.emit).
* ``python -m benchmarks.run --gate`` — the unified gate runner: every
  ``benchmarks/*_bench.py`` that supports ``--gate`` runs in its own
  subprocess (a crashed bench can't take down the others), their
  ``BENCH_*.json`` artifacts merge into ``BENCH_all.json``, one run
  record lands in ``results/history/bench_all.jsonl``, and the exit
  code is nonzero if any gate failed. A ``*_bench.py`` without a
  ``--gate`` flag (argparse exit code 2) is reported as skipped, not
  failed. CI runs this one entry point instead of one job per bench.
"""
from __future__ import annotations

import argparse
import glob
import json
import subprocess
import sys
import time
from pathlib import Path

from benchmarks.common import append_history


def smoke() -> None:
    # benchmarks.scenarios_grid is not in this list: it runs (gated, with
    # its BENCH_scenarios.json artifact) in its own CI job.
    from benchmarks import (fig4_continual, fig5a_quant_error,
                            fig5b_endurance, fig5c_latency, fig5d_power,
                            kernel_bench, roofline_bench,
                            table1_throughput)
    t0 = time.time()
    print("name,us_per_call,derived")
    table1_throughput.run(fast=True)
    fig5c_latency.run()
    fig5d_power.run()
    fig5a_quant_error.run()
    fig5b_endurance.run()
    kernel_bench.run()
    fig4_continual.run(fast=True)
    roofline_bench.run()
    print(f"# total_bench_seconds={time.time() - t0:.1f}", file=sys.stderr)


def _gated_benches() -> list[str]:
    """Module names of every ``benchmarks/*_bench.py``, sorted — the gate
    contract is the filename pattern, not a hand-maintained list."""
    here = Path(__file__).resolve().parent
    return sorted(p.stem for p in here.glob("*_bench.py"))


def run_gates(benches: list[str] | None = None) -> dict:
    t_start = time.time()
    merged: dict = {"benches": {}, "gates": {}}
    for name in (benches or _gated_benches()):
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", f"benchmarks.{name}", "--gate"],
            capture_output=True, text=True)
        wall = time.time() - t0
        sys.stdout.write(proc.stdout)
        sys.stderr.write(proc.stderr)
        if proc.returncode == 2:            # argparse: no --gate flag
            merged["benches"][name] = {"status": "skipped",
                                       "reason": "no --gate support"}
            print(f"# {name}: skipped (no --gate)", file=sys.stderr)
            continue
        status = "pass" if proc.returncode == 0 else "fail"
        entry: dict = {"status": status, "wall_s": wall,
                       "returncode": proc.returncode}
        # Each gated bench writes its own BENCH_*.json in cwd; fold any
        # artifact this subprocess (re)wrote into the merged report.
        for p in glob.glob("BENCH_*.json"):
            if p == "BENCH_all.json" or Path(p).stat().st_mtime < t0:
                continue
            try:
                payload = json.loads(Path(p).read_text())
            except (OSError, json.JSONDecodeError):
                continue
            entry.setdefault("artifacts", {})[p] = payload
            for g, ok in (payload.get("gates") or {}).items():
                merged["gates"][f"{name}/{g}"] = bool(ok)
        merged["benches"][name] = entry
    merged["wall_s"] = time.time() - t_start
    merged["ok"] = (all(merged["gates"].values())
                    and not any(b.get("status") == "fail"
                                for b in merged["benches"].values()))
    return merged


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="run every *_bench.py --gate, merge artifacts "
                         "into BENCH_all.json, exit nonzero on failure")
    ap.add_argument("--bench", action="append", default=None,
                    metavar="NAME",
                    help="restrict --gate to these bench module names "
                         "(repeatable)")
    args = ap.parse_args()
    if not args.gate:
        smoke()
        return 0
    merged = run_gates(args.bench)
    Path("BENCH_all.json").write_text(
        json.dumps(merged, indent=1, default=float))
    print("wrote BENCH_all.json")
    append_history(
        "bench_all",
        {"wall_s": merged["wall_s"],
         "statuses": {k: v["status"]
                      for k, v in merged["benches"].items()}},
        gates=merged["gates"])
    if not merged["ok"]:
        failed = [k for k, v in merged["gates"].items() if not v] + \
            [k for k, v in merged["benches"].items()
             if v.get("status") == "fail"]
        print(f"GATE FAILURE: {failed}", file=sys.stderr)
        return 1
    print(f"all gates passed ({len(merged['gates'])} gates, "
          f"{merged['wall_s']:.0f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
