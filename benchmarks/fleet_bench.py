"""Fleet-simulation benchmark — scaling efficiency + parity + aggregate.

Runs the sharded fleet runner (:mod:`repro.fleet`) under emulated host
devices and emits ``BENCH_fleet.json`` with three gates:

  scaling_efficiency_ge_0.8
      Wall-time of the sharded fleet program vs the single-program
      seed-vmapped ``run_compiled`` baseline doing the *same total
      work*. Emulated CPU devices share the same host cores, so ideal
      (linear) sharding is wall-time parity with the vmap baseline —
      the gate bounds the overhead ``shard_map`` + mesh transfer adds:
      ``efficiency = t_vmap / t_fleet ≥ 0.8``.
  zero_het_parity_bitwise
      A ``het_profile="none"`` fleet must reproduce ``run_compiled``'s
      per-seed results bit for bit (R matrices and final params).
  aggregate_schema
      The fleet-aggregate report carries p50/p95/p99 distributions for
      power (mW), GOPS/W, lifetime (years) and forgetting, from a
      metered heterogeneous run on the conductance-domain backend.

Run directly (defaults to 8 emulated devices when XLA_FLAGS is unset)::

    python benchmarks/fleet_bench.py --gate
"""
from __future__ import annotations

import os

if "--help" not in __import__("sys").argv and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import argparse
import json
from pathlib import Path

import numpy as np

from benchmarks.common import append_history, emit, save_json

FLEET_DEVICES = 8
#: Minimum acceptable t_vmap / t_fleet (sharding-overhead bound).
EFFICIENCY_FLOOR = 0.8


def _workload():
    from repro.core.continual import TrainerSpec
    from repro.scenarios import build_scenario
    from repro.scenarios.sweep import scenario_miru_config

    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=128,
                           n_test=64)
    cfg = scenario_miru_config(tasks, n_h=32)
    return cfg, TrainerSpec(algo="dfa", epochs_per_task=1), tasks


def measure_parity_and_scaling() -> dict:
    """Zero-heterogeneity fleet vs the seed-vmapped baseline: bitwise
    parity plus the wall-time ratio (best of three runs each — both
    paths pay one compile per call, so the ratio compares like with
    like)."""
    from repro.core.continual import ReplaySpec
    from repro.fleet import FleetSpec, device_seeds, run_fleet
    from repro.scenarios import run_compiled

    cfg, trainer, tasks = _workload()
    fleet = FleetSpec(n_devices=FLEET_DEVICES, het_profile="none", seed=0)
    seeds = device_seeds(fleet)
    rspec = ReplaySpec(capacity=32)

    fleet_runs = [run_fleet(cfg, trainer, tasks, fleet, replay=rspec,
                            device="ideal") for _ in range(3)]
    base_runs = [run_compiled(cfg, trainer, tasks, replay=rspec,
                              device="ideal", seeds=seeds)
                 for _ in range(3)]
    fl, rc = fleet_runs[0], base_runs[0]

    parity = all(
        np.array_equal(fl["per_device"][i]["R_full"],
                       rc["per_seed"][i]["R_full"])
        for i in range(FLEET_DEVICES)) and all(
        np.array_equal(np.asarray(fl["params"][k]), np.asarray(v))
        for k, v in rc["params"].items())

    t_fleet = min(r["wall_s"] for r in fleet_runs)
    t_vmap = min(r["wall_s"] for r in base_runs)
    return {
        "n_devices": FLEET_DEVICES,
        "n_shards": fl["n_shards"],
        "t_fleet_s": t_fleet,
        "t_vmap_baseline_s": t_vmap,
        "scaling_efficiency": t_vmap / t_fleet,
        "parity_bitwise": bool(parity),
    }


def measure_aggregate() -> dict:
    """Metered heterogeneous fleet on the conductance-domain backend →
    the population-distribution report."""
    from repro.backends import get_backend
    from repro.core.continual import ReplaySpec
    from repro.fleet import FleetSpec, fleet_aggregate, run_fleet
    from repro.telemetry.report import format_fleet

    from repro.core.continual import TrainerSpec
    from repro.scenarios import build_scenario
    from repro.scenarios.sweep import scenario_miru_config

    tasks = build_scenario("permuted", seed=0, n_tasks=2, n_train=64,
                           n_test=32)
    cfg = scenario_miru_config(tasks, n_h=24)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=1)

    backend = get_backend("analog_state")
    backend.telemetry.enable()
    fleet = FleetSpec(n_devices=FLEET_DEVICES, het_profile="mild", seed=1)
    fl = run_fleet(cfg, trainer, tasks, fleet,
                   replay=ReplaySpec(capacity=32), device=backend)
    agg = fleet_aggregate(fl)
    print(format_fleet(agg))
    return agg


def aggregate_schema_ok(agg: dict) -> bool:
    return all(
        key in agg and {"p50", "p95", "p99"} <= set(agg[key])
        for key in ("power_mw", "gops_per_w", "lifetime_years",
                    "forgetting"))


def run() -> dict:
    out: dict = {"devices_emulated": FLEET_DEVICES}
    sc = measure_parity_and_scaling()
    out["scaling"] = sc
    emit("fleet/scaling", sc["t_fleet_s"] * 1e6,
         f"eff={sc['scaling_efficiency']:.2f}x;"
         f"shards={sc['n_shards']};parity={sc['parity_bitwise']}")

    agg = measure_aggregate()
    out["aggregate"] = agg
    emit("fleet/aggregate", 0,
         f"lifetime_p99={agg['lifetime_years']['p99']:.1f}y;"
         f"forget_p95={agg['forgetting']['p95']:+.3f}")

    out["gates"] = {
        f"scaling_efficiency_ge_{EFFICIENCY_FLOOR}":
            sc["scaling_efficiency"] >= EFFICIENCY_FLOOR,
        "zero_het_parity_bitwise": sc["parity_bitwise"],
        "aggregate_schema": aggregate_schema_ok(agg),
    }
    save_json("fleet_bench", out)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero when a gate fails")
    args = ap.parse_args()
    out = run()
    Path("BENCH_fleet.json").write_text(
        json.dumps(out, indent=1, default=float))
    print("wrote BENCH_fleet.json")
    if args.gate:
        append_history(
            "fleet_bench",
            {"scaling_efficiency": out["scaling"]["scaling_efficiency"],
             "t_fleet_s": out["scaling"]["t_fleet_s"]},
            gates=out["gates"])
    ok = all(out["gates"].values())
    if not ok:
        print(f"GATE FAILURE: {out['gates']}")
    return 0 if (ok or not args.gate) else 1


if __name__ == "__main__":
    raise SystemExit(main())
