"""The observability cost + neutrality gates (repro.obs).

Three claims from docs/observability.md, checked on the paper's
28×100×10 continual-learning config (permuted scenario, batch 32, wbs
substrate):

  * **disabled is free** — ``obs=None`` builds the exact pre-obs
    program: R / params / losses bitwise identical to an obs-enabled
    run's (the streams are pure reads, so enabled is bitwise-inert on
    results too). Gate: ``bitwise_neutral``.
  * **enabled is cheap** — the extra scan outputs cost ≤ 5 % execute
    time. Both variants are AOT-compiled once and timed over the same
    buffers (best-of-N executions), so the comparison excludes
    trace/compile noise. Gate: ``overhead_le_5pct``.
  * **streams sum exact** — the write-pulse time series totals exactly
    to the aggregate ``write_pulses`` telemetry counter of the same
    metered run. Gate: ``stream_sum_equals_counter``.

``python -m benchmarks.obs_bench --gate`` writes ``BENCH_obs.json`` and
exits nonzero on any gate failure; ``--trace``/``--record`` additionally
emit the Chrome trace and the run-record JSONL the CI ``obs-smoke`` job
uploads as artifacts.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import append_history, emit, save_json

# Paper geometry: 28 features × 100 hidden × 10 classes, T=28, batch 32.
N_H = 100
N_TASKS = 3
EPOCHS = 2


def _setup():
    from repro.backends import get_backend
    from repro.core.continual import ReplaySpec, TrainerSpec
    from repro.scenarios import build_scenario, scenario_miru_config

    tasks = build_scenario("permuted", seed=0, n_tasks=N_TASKS,
                           n_train=600, n_test=200)
    cfg = scenario_miru_config(tasks, n_h=N_H)
    trainer = TrainerSpec(epochs_per_task=EPOCHS, batch_size=32)
    rspec = ReplaySpec(capacity=512)
    return cfg, trainer, rspec, tasks, get_backend("wbs")


def bench_overhead(iters: int = 5) -> dict:
    """Execute-time cost of the in-scan metric streams: the same
    whole-protocol program compiled with and without the obs outputs,
    both AOT so only execution is timed. The two variants are timed
    *interleaved* (disabled, enabled, disabled, ...) and best-of-
    ``iters`` taken per variant, so machine-load drift between the two
    measurement phases can't masquerade as obs overhead."""
    from repro.core.continual import _make_raw_steps
    from repro.scenarios.sweep import (_build_seed_inputs, _make_run_fn)

    cfg, trainer, rspec, tasks, backend = _setup()
    _, _, opt = _make_raw_steps(cfg, trainer, backend)
    inp, sched = _build_seed_inputs(cfg, trainer, rspec, backend, tasks,
                                    opt)
    n_tasks, S = len(tasks), inp.xs.shape[1]
    eval_x = np.stack([t.x_test for t in tasks])
    eval_y = np.stack([t.y_test for t in tasks])
    args = inp.as_arrays() + (jax.numpy.asarray(eval_x),
                              jax.numpy.asarray(eval_y))

    out: dict = {"steps": n_tasks * S,
                 "config": {"n_h": N_H, "n_tasks": n_tasks,
                            "steps_per_task": S, "backend": "wbs"}}
    compiled = {}
    for label, obs_metrics in (("disabled", False), ("enabled", True)):
        run = _make_run_fn(cfg, trainer, backend, n_tasks, S,
                           track_writes=False, baseline=False,
                           obs_metrics=obs_metrics)
        compiled[label] = jax.jit(run).lower(*args).compile()
        jax.block_until_ready(compiled[label](*args))    # warm
    times = {label: float("inf") for label in compiled}
    for _ in range(iters):
        for label, fn in compiled.items():               # interleaved
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times[label] = min(times[label], time.perf_counter() - t0)
    for label, best in times.items():
        out[label] = {"execute_s": best}
        emit(f"obs/execute_{label}", best * 1e6,
             f"best_of_{iters};{n_tasks}x{S}steps_nh{N_H}")
    out["overhead_pct"] = (times["enabled"] - times["disabled"]) \
        / times["disabled"] * 100.0
    emit("obs/overhead", times["enabled"] * 1e6,
         f"{out['overhead_pct']:+.2f}%_vs_disabled")
    return out


def bench_neutrality(tracer=None) -> dict:
    """End-to-end bitwise comparison through the public runner: the same
    ``run_compiled`` call with ``obs=None`` vs a full ObsSpec, plus the
    stream-sum-equals-counter check on the metered variant."""
    from repro.obs import ObsSpec
    from repro.scenarios import run_compiled

    cfg, trainer, rspec, tasks, backend = _setup()
    base = run_compiled(cfg, trainer, tasks, replay=rspec, device=backend)
    backend.telemetry.enable()
    obs = ObsSpec(cadence=10, tracer=tracer)
    res = run_compiled(cfg, trainer, tasks, replay=rspec, device=backend,
                       obs=obs)
    backend.telemetry.disable()

    bitwise = (
        np.array_equal(np.asarray(base["R"]), np.asarray(res["R"]))
        and base["losses"] == res["losses"]
        and all(np.array_equal(np.asarray(base["params"][k]),
                               np.asarray(res["params"][k]))
                for k in base["params"]))
    log = res["runlog"]
    counter = sum(v for k, v in backend.telemetry.snapshot().items()
                  if k.startswith("write_pulses/"))
    out = {
        "bitwise_neutral": bool(bitwise),
        "stream_total_write_pulses": int(log.total_write_pulses),
        "counter_write_pulses": int(counter),
        "stream_sum_equals_counter":
            int(log.total_write_pulses) == int(counter),
        "n_windows": log.n_windows,
        "compile_s": res.get("compile_s"),
        "execute_s": res.get("execute_s"),
    }
    emit("obs/neutrality", 0.0,
         f"bitwise={out['bitwise_neutral']};"
         f"stream_sum={out['stream_sum_equals_counter']}")
    return out, log


def run(iters: int = 3, tracer=None) -> dict:
    out: dict = {}
    out["overhead"] = bench_overhead(iters=iters)
    out["neutrality"], runlog = bench_neutrality(tracer=tracer)
    out["gates"] = {
        "overhead_le_5pct": out["overhead"]["overhead_pct"] <= 5.0,
        "bitwise_neutral": out["neutrality"]["bitwise_neutral"],
        "stream_sum_equals_counter":
            out["neutrality"]["stream_sum_equals_counter"],
    }
    out["_runlog"] = runlog          # popped before serialization
    save_json("obs_bench", {k: v for k, v in out.items()
                            if k != "_runlog"})
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="write BENCH_obs.json and exit nonzero when the "
                         "overhead/neutrality gates fail")
    ap.add_argument("--iters", type=int, default=5,
                    help="best-of-N executions for the overhead timing")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the gate run's Chrome trace.json")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="append a run-record JSONL (timeline included)")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(process_name="obs_bench")
    out = run(iters=args.iters, tracer=tracer)
    runlog = out.pop("_runlog")

    if tracer is not None:
        print(f"wrote {tracer.export_chrome(args.trace)}")
    if args.record:
        from repro.obs import JsonlSink, run_record
        rec = run_record(
            "bench", "obs_bench",
            {"overhead_pct": out["overhead"]["overhead_pct"],
             "execute_disabled_s": out["overhead"]["disabled"]["execute_s"],
             "execute_enabled_s": out["overhead"]["enabled"]["execute_s"]},
            gates=out["gates"],
            timeline=runlog.as_dict(max_points=200))
        print(f"wrote {JsonlSink(args.record).emit(rec)}")
    if args.gate:
        Path("BENCH_obs.json").write_text(
            json.dumps(out, indent=1, default=float))
        print("wrote BENCH_obs.json")
        append_history(
            "obs_bench",
            {"overhead_pct": out["overhead"]["overhead_pct"],
             "execute_disabled_s": out["overhead"]["disabled"]["execute_s"],
             "execute_enabled_s": out["overhead"]["enabled"]["execute_s"]},
            gates=out["gates"])
        ok = all(out["gates"].values())
        if not ok:
            print(f"GATE FAILURE: {out['gates']}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
