"""Roofline tables from the dry-run artifacts (§Roofline / §Perf).

Prints the full baseline table, the optimized (ulysses) table, and the
pallas-flash-adjusted memory terms, if the corresponding dry-run JSONs
exist (produced by repro.launch.dryrun)."""
from __future__ import annotations

from repro.launch.roofline import summarize

from benchmarks.common import emit, save_json


def run() -> dict:
    out = {}
    for tag, label, adj in (("", "baseline", False),
                            ("opt", "ulysses", False),
                            ("opt", "ulysses+flash", True)):
        rows = summarize("16x16", tag, flash_adjust=adj)
        if not rows:
            continue
        out[label] = []
        for r in rows:
            out[label].append({
                "arch": r.arch, "shape": r.shape,
                "compute_ms": r.compute_s * 1e3,
                "memory_ms": r.memory_s * 1e3,
                "collective_ms": r.collective_s * 1e3,
                "bound": r.bound, "useful": r.useful_ratio,
                "roofline_frac": r.roofline_frac,
            })
            emit(f"roofline/{label}/{r.arch}/{r.shape}", 0.0,
                 f"bound={r.bound};frac={r.roofline_frac*100:.0f}%;"
                 f"useful={r.useful_ratio:.2f}")
    save_json("roofline_bench", out)
    return out


if __name__ == "__main__":
    run()
