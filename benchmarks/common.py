"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
RESULTS.mkdir(parents=True, exist_ok=True)


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (CPU timing — context
    for the derived numbers, not a TPU claim)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload) -> Path:
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p
