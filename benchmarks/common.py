"""Shared benchmark utilities."""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
RESULTS.mkdir(parents=True, exist_ok=True)
HISTORY = RESULTS / "history"


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (CPU timing — context
    for the derived numbers, not a TPU claim)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload) -> Path:
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


def append_history(name: str, metrics: dict, *,
                   gates: dict | None = None,
                   extra: dict | None = None) -> Path | None:
    """Append a schema-versioned run record (repro.obs.sinks) to
    ``results/history/<name>.jsonl`` — the per-commit perf trajectory
    behind the point-in-time ``BENCH_*.json`` gates. Returns the path,
    or None when ``repro.obs`` is not importable (benchmarks stay
    runnable from a partial checkout)."""
    try:
        from repro.obs import JsonlSink, run_record
    except ImportError:
        print(f"# history append skipped for {name}: repro.obs not "
              f"importable", file=sys.stderr)
        return None
    rec = run_record("bench", name, metrics, gates=gates, extra=extra)
    return JsonlSink(HISTORY / f"{name}.jsonl").emit(rec)
