"""Fig. 5a: average VMM error during replay — uniform vs stochastic
quantization across bit widths. Paper claim: stochastic 4-bit keeps the
error below ~5 %."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.replay import (dequantize, stochastic_quantize,
                               uniform_quantize)

from benchmarks.common import emit, save_json


def run() -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (256, 784))
    w = jax.random.normal(jax.random.PRNGKey(1), (784, 100)) * 0.05
    exact = x @ w
    ref = float(jnp.abs(exact).mean())
    out = {}
    for bits in (2, 3, 4, 6, 8):
        t0 = time.time()
        errs = {}
        for name, quant in (("stochastic", stochastic_quantize),
                            ("uniform", lambda a, k=None, b=bits:
                             uniform_quantize(a, b))):
            if name == "stochastic":
                xq = dequantize(quant(x, jax.random.PRNGKey(2), bits),
                                bits)
            else:
                xq = dequantize(quant(x), bits)
            errs[name] = float(jnp.abs(xq @ w - exact).mean()) / ref
        out[f"bits{bits}"] = errs
        emit(f"fig5a/bits{bits}", (time.time() - t0) * 1e6,
             f"stoch={errs['stochastic']*100:.2f}%;"
             f"unif={errs['uniform']*100:.2f}%")
    assert out["bits4"]["stochastic"] < 0.05, "paper's ≤5 % claim"
    save_json("fig5a_quant_error", out)
    return out


if __name__ == "__main__":
    run()
