"""Kernel micro-benchmarks + the fused-recurrence perf gate.

Two layers:

  * per-kernel sweeps (WBS matmul / MiRU scans / k-WTA / flash fwd) vs
    their jnp references — CPU interpret-mode timings for correctness and
    relative-cost context, not TPU numbers;
  * the **fused vs per-step device recurrence** comparison on the paper's
    28×100×10 continual-learning config: end-to-end
    ``miru_forward_device`` wall time on the wbs substrate, bitwise
    parity, metered GOPS/W per path from the run's own telemetry
    (repro.telemetry), and the pad/scale-hoist win.

``python -m benchmarks.kernel_bench --gate`` writes ``BENCH_kernels.json``
and exits nonzero unless the fused path is ≥ 2× the per-step path AND
bit-identical — the kernel-level perf trajectory baseline gated on main.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import append_history, emit, save_json, time_call

# The paper's Fig. 4 geometry: 28 features × 100 hidden × 10 classes,
# T=28 time steps (row-serial MNIST), batch 32.
PAPER = dict(B=32, T=28, K=28, H=100, n_y=10)


def bench_fused_recurrence(iters: int = 30) -> dict:
    """Fused one-kernel scan vs the per-timestep device_vmm loop, through
    the public ``miru_forward_device`` on the wbs backend (zero noise ⇒
    deterministic, parity checkable)."""
    from repro.analog.costmodel import M2RUCostModel
    from repro.backends import get_backend
    from repro.core.continual import miru_forward_device
    from repro.core.miru import MiRUConfig, init_miru_params
    from repro.telemetry import telemetry_report

    p = PAPER
    cfg = MiRUConfig(n_x=p["K"], n_h=p["H"], n_y=p["n_y"])
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (p["B"], p["T"], p["K"]),
                           minval=-1, maxval=1)
    key = jax.random.PRNGKey(2)

    out: dict = {"config": dict(p)}
    results = {}
    for label, fused in (("per_step", False), ("fused", True)):
        backend = get_backend("wbs")
        fn = jax.jit(lambda pr, xs, k, f=fused, b=backend:
                     miru_forward_device(pr, cfg, xs, k, b, fused=f))
        logits, aux = fn(params, x, key)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(params, x, key)[0])
        us = (time.perf_counter() - t0) / iters * 1e6
        # Metered GOPS/W for this path from its own activity counters
        # (PR-2 telemetry): re-trace with metering on, then fold through
        # the energy model.
        mb = get_backend("wbs")
        mb.telemetry.enable()
        mfn = jax.jit(lambda pr, xs, k, f=fused, b=mb:
                      miru_forward_device(pr, cfg, xs, k, b, fused=f)[0])
        jax.block_until_ready(mfn(params, x, key))
        rep = telemetry_report(mb.telemetry, model=M2RUCostModel(n_h=p["H"]))
        results[label] = {
            "us": us,
            "logits": np.asarray(logits),
            "aux": {k: np.asarray(v) for k, v in aux.items()},
            "counters": mb.telemetry.snapshot(),
            "gops_per_w": rep["metered"]["gops_per_w"],
            "power_mw": rep["metered"]["power_mw"],
        }
        out[label] = {"us": us,
                      "gops_per_w": rep["metered"]["gops_per_w"],
                      "power_mw": rep["metered"]["power_mw"]}
        emit(f"kernel/recurrence_{label}", us,
             f"{rep['metered']['gops_per_w']:.0f}GOPS/W;"
             f"B{p['B']}_T{p['T']}_K{p['K']}_H{p['H']}")

    parity = bool(np.array_equal(results["fused"]["logits"],
                                 results["per_step"]["logits"]))
    for k in results["fused"]["aux"]:
        parity = parity and bool(np.array_equal(
            results["fused"]["aux"][k], results["per_step"]["aux"][k]))
    counters_equal = (results["fused"]["counters"]
                      == results["per_step"]["counters"])
    speedup = results["per_step"]["us"] / results["fused"]["us"]
    out.update({"speedup": speedup, "parity_bitwise": parity,
                "counters_equal": counters_equal})
    emit("kernel/recurrence_speedup", results["fused"]["us"],
         f"{speedup:.2f}x_vs_per_step;parity={parity};"
         f"counters={counters_equal}")
    return out


def bench_pad_hoist(iters: int = 50) -> dict:
    """The satellite measurement: what the per-step path pays to re-pad
    and re-scale w/u on every timestep — one padded-shape ``wbs_matmul``
    call vs one call on pre-padded inputs (the fused scan pays the
    padding exactly once per forward instead of T times)."""
    K, H, B = PAPER["K"], PAPER["H"], PAPER["B"]
    x = jax.random.uniform(jax.random.PRNGKey(0), (B, K),
                           minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, H)) * 0.3
    sign, code = ops.quantize_inputs(x, 8)
    gains = 2.0 ** (-jnp.arange(1, 9, dtype=jnp.float32))

    us_unpadded = time_call(lambda: ops.wbs_matmul(sign, code, w, gains)
                            .block_until_ready(), iters=iters)
    from repro.kernels.wbs_matmul import wbs_matmul_pallas
    from repro.utils import round_up
    bm = min(128, round_up(B, 8))
    Kp, Hp = round_up(K, 128), round_up(H, 128)
    sp = jnp.pad(sign, ((0, round_up(B, bm) - B), (0, Kp - K)))
    cp = jnp.pad(code, ((0, round_up(B, bm) - B), (0, Kp - K)))
    wp = jnp.pad(w, ((0, Kp - K), (0, Hp - H)))
    interp = jax.default_backend() == "cpu"
    us_prepadded = time_call(
        lambda: wbs_matmul_pallas(sp, cp, wp, gains, bm=bm, bk=128, bn=128,
                                  interpret=interp).block_until_ready(),
        iters=iters)
    overhead = us_unpadded - us_prepadded
    emit("kernel/wbs_matmul_pad_overhead", overhead,
         f"unpadded={us_unpadded:.0f}us;prepadded={us_prepadded:.0f}us;"
         f"x{PAPER['T']}_per_fwd_in_per_step_scan")
    return {"unpadded_us": us_unpadded, "prepadded_us": us_prepadded,
            "per_call_overhead_us": overhead,
            "per_forward_overhead_us": overhead * PAPER["T"]}


def run() -> dict:
    out = {}
    key = jax.random.PRNGKey(0)

    # WBS matmul
    x = jax.random.uniform(key, (256, 256), minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    sign, code = ops.quantize_inputs(x, 8)
    gains = 2.0 ** (-jnp.arange(1, 9, dtype=jnp.float32))
    us_k = time_call(lambda: ops.wbs_matmul(sign, code, w, gains)
                     .block_until_ready())
    us_r = time_call(lambda: ref.wbs_matmul_ref(sign, code, w, gains)
                     .block_until_ready())
    out["wbs_matmul"] = {"kernel_us": us_k, "ref_us": us_r}
    emit("kernel/wbs_matmul", us_k, f"ref={us_r:.0f}us;256x256x256_8bit")

    # MiRU scan (ideal float recurrence)
    xw = jax.random.normal(key, (32, 28, 128))
    u = jax.random.normal(jax.random.PRNGKey(2), (128, 128)) * 0.3
    h0 = jnp.zeros((32, 128))
    us_k = time_call(lambda: ops.miru_scan(xw, u, h0, 0.8, 0.5)[0]
                     .block_until_ready())
    us_r = time_call(lambda: ref.miru_scan_ref(xw, u, h0, 0.8, 0.5)[0]
                     .block_until_ready())
    out["miru_scan"] = {"kernel_us": us_k, "ref_us": us_r}
    emit("kernel/miru_scan", us_k, f"ref={us_r:.0f}us;B32_T28_H128")

    # Fused device-true recurrence (quantized) — interpret-mode kernel vs
    # the jnp reference it dispatches to on CPU.
    drive = jax.random.normal(jax.random.PRNGKey(6), (8, 28, 128))
    b_h = jnp.zeros((128,))
    kw = dict(beta=0.8, lam=0.5, n_bits=8, adc_bits=8, weight_scale=1.5)
    us_k = time_call(lambda: ops.wbs_miru_scan(
        drive, u, b_h, use_kernel=True, **kw)[0].block_until_ready())
    us_r = time_call(lambda: ops.wbs_miru_scan(
        drive, u, b_h, use_kernel=False, **kw)[0].block_until_ready())
    out["wbs_miru_scan"] = {"kernel_us": us_k, "ref_us": us_r}
    emit("kernel/wbs_miru_scan", us_k, f"ref={us_r:.0f}us;B8_T28_H128_8bit")

    # k-WTA
    g = jax.random.normal(jax.random.PRNGKey(3), (64, 1024))
    us_k = time_call(lambda: ops.kwta(g, 580).block_until_ready())
    us_r = time_call(lambda: ref.kwta_ref(g, 580).block_until_ready())
    out["kwta"] = {"kernel_us": us_k, "ref_us": us_r}
    emit("kernel/kwta", us_k, f"ref={us_r:.0f}us;64x1024_k580")

    # Flash attention fwd (GQA heads shared via the index map, no repeat)
    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 256, 2, 64))
    us_k = time_call(lambda: ops.flash_attention_fwd(q, k, v, True)[0]
                     .block_until_ready())
    out["flash_fwd"] = {"kernel_us": us_k}
    emit("kernel/flash_fwd", us_k, "B2_S256_H4kv2_dh64_no_kv_repeat")

    # The headline comparison + satellites.
    out["fused_recurrence"] = bench_fused_recurrence()
    out["pad_hoist"] = bench_pad_hoist()
    out["gates"] = {
        "fused_speedup_ge_2x": out["fused_recurrence"]["speedup"] >= 2.0,
        "fused_parity_bitwise": out["fused_recurrence"]["parity_bitwise"],
        "telemetry_counters_equal":
            out["fused_recurrence"]["counters_equal"],
    }
    save_json("kernel_bench", out)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="write BENCH_kernels.json and exit nonzero when "
                         "the fused-recurrence gates fail")
    args = ap.parse_args()
    out = run()
    if args.gate:
        Path("BENCH_kernels.json").write_text(
            json.dumps(out, indent=1, default=float))
        print("wrote BENCH_kernels.json")
        append_history(
            "kernel_bench",
            {"fused_speedup": out["fused_recurrence"]["speedup"],
             "per_step_us": out["fused_recurrence"]["per_step"]["us"],
             "fused_us": out["fused_recurrence"]["fused"]["us"]},
            gates=out["gates"])
        ok = all(out["gates"].values())
        if not ok:
            print(f"GATE FAILURE: {out['gates']}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
