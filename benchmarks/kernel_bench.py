"""Kernel micro-benchmarks: WBS matmul / fused MiRU scan / k-WTA / flash
fwd vs their jnp references (CPU interpret-mode timings — correctness +
relative cost context, not TPU numbers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from benchmarks.common import emit, save_json, time_call


def run() -> dict:
    out = {}
    key = jax.random.PRNGKey(0)

    # WBS matmul
    x = jax.random.uniform(key, (256, 256), minval=-1, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    sign, code = ops.quantize_inputs(x, 8)
    gains = 2.0 ** (-jnp.arange(1, 9, dtype=jnp.float32))
    us_k = time_call(lambda: ops.wbs_matmul(sign, code, w, gains)
                     .block_until_ready())
    us_r = time_call(lambda: ref.wbs_matmul_ref(sign, code, w, gains)
                     .block_until_ready())
    out["wbs_matmul"] = {"kernel_us": us_k, "ref_us": us_r}
    emit("kernel/wbs_matmul", us_k, f"ref={us_r:.0f}us;256x256x256_8bit")

    # MiRU scan
    xw = jax.random.normal(key, (32, 28, 128))
    u = jax.random.normal(jax.random.PRNGKey(2), (128, 128)) * 0.3
    h0 = jnp.zeros((32, 128))
    us_k = time_call(lambda: ops.miru_scan(xw, u, h0, 0.8, 0.5)[0]
                     .block_until_ready())
    us_r = time_call(lambda: ref.miru_scan_ref(xw, u, h0, 0.8, 0.5)[0]
                     .block_until_ready())
    out["miru_scan"] = {"kernel_us": us_k, "ref_us": us_r}
    emit("kernel/miru_scan", us_k, f"ref={us_r:.0f}us;B32_T28_H128")

    # k-WTA
    g = jax.random.normal(jax.random.PRNGKey(3), (64, 1024))
    us_k = time_call(lambda: ops.kwta(g, 580).block_until_ready())
    us_r = time_call(lambda: ref.kwta_ref(g, 580).block_until_ready())
    out["kwta"] = {"kernel_us": us_k, "ref_us": us_r}
    emit("kernel/kwta", us_k, f"ref={us_r:.0f}us;64x1024_k580")

    # Flash attention fwd
    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 256, 2, 64))
    us_k = time_call(lambda: ops.flash_attention_fwd(q, k, v, True)[0]
                     .block_until_ready())
    out["flash_fwd"] = {"kernel_us": us_k}
    emit("kernel/flash_fwd", us_k, "B2_S256_H4_dh64")

    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    run()
