"""Fig. 4: domain-incremental continual learning — Adam vs DFA vs the
mixed-signal hardware model, n_h ∈ {100, 256}, permuted + split streams.

Validates (on matched-geometry synthetic streams — DESIGN.md §8):
  * replay prevents catastrophic forgetting (graceful degradation),
  * DFA within a few points of the Adam baseline,
  * hardware model within 5 % of software DFA (the paper's ≤5 % claim),
  * n_h=256 narrows the hw/software gap (paper: 4.93 % → 2.48 %).
"""
from __future__ import annotations

import time

from repro.core.continual import ContinualConfig, run_continual
from repro.core.miru import MiRUConfig
from repro.data.synthetic import make_permuted_tasks, make_split_tasks

from benchmarks.common import emit, save_json

FAST = {"n_tasks": 4, "n_train": 500, "n_test": 200, "epochs": 6}


def run(fast: bool = True) -> dict:
    p = FAST
    out: dict = {}
    for stream, mk in [("permuted", make_permuted_tasks),
                       ("split", make_split_tasks)]:
        for n_h in (100, 256) if not fast else (100,):
            tasks = mk(0, n_tasks=p["n_tasks"], n_train=p["n_train"],
                       n_test=p["n_test"])
            T, F = tasks[0].x_train.shape[1:]
            n_y = int(max(t.y_train.max() for t in tasks)) + 1
            cfg = MiRUConfig(n_x=F, n_h=n_h, n_y=n_y)
            for trainer in ("adam", "dfa", "dfa_hw"):
                t0 = time.time()
                # Legacy trainer strings resolve through the backend
                # registry: "dfa_hw" ≡ DFA on the "analog" substrate.
                tspec, rspec, backend = ContinualConfig(
                    trainer=trainer, epochs_per_task=p["epochs"],
                    batch_size=32, replay_capacity=512).specs()
                res = run_continual(cfg, tspec, tasks, replay=rspec,
                                    device=backend)
                key = f"{stream}_nh{n_h}_{trainer}"
                out[key] = {"MA": res["MA"],
                            "acc_after_each": res["acc_after_each"],
                            "final_row": res["R"][-1].tolist()}
                emit(f"fig4/{key}", (time.time() - t0) * 1e6,
                     f"MA={res['MA']:.3f}")
    # Headline deltas.
    for stream in ("permuted", "split"):
        sw = out[f"{stream}_nh100_dfa"]["MA"]
        hw = out[f"{stream}_nh100_dfa_hw"]["MA"]
        adam = out[f"{stream}_nh100_adam"]["MA"]
        out[f"{stream}_gaps"] = {"hw_vs_dfa": sw - hw,
                                 "dfa_vs_adam": adam - sw}
        emit(f"fig4/{stream}_hw_gap", 0.0,
             f"hw_gap={sw - hw:+.3f};dfa_vs_adam={adam - sw:+.3f}")
    save_json("fig4_continual", out)
    return out


if __name__ == "__main__":
    run()
