"""Fig. 4: domain-incremental continual learning — Adam vs DFA vs the
mixed-signal hardware model, n_h ∈ {100, 256}, permuted + split streams.

Runs through the ``repro.scenarios`` compiled sweep (scan-over-tasks in
one jit; the per-task Python loop remains available via
``core.continual.run_continual`` and is bit-identical on the ideal
backend — asserted in tests and gated in benchmarks/scenarios_grid.py).

Validates (on matched-geometry synthetic streams — DESIGN.md §8):
  * replay prevents catastrophic forgetting (graceful degradation),
  * DFA within a few points of the Adam baseline,
  * hardware model within 5 % of software DFA (the paper's ≤5 % claim),
  * n_h=256 narrows the hw/software gap (paper: 4.93 % → 2.48 %).
"""
from __future__ import annotations

import time

from repro.core.continual import ReplaySpec, TrainerSpec
from repro.scenarios import (build_scenario, run_compiled,
                             scenario_miru_config)

from benchmarks.common import emit, save_json

FAST = {"n_tasks": 4, "n_train": 500, "n_test": 200, "epochs": 6}

# The paper's three training setups: (label, learning rule, substrate).
SETUPS = [("adam", "adam", "ideal"),
          ("dfa", "dfa", "ideal"),
          ("dfa_hw", "dfa", "analog")]


def run(fast: bool = True) -> dict:
    p = FAST
    out: dict = {}
    for stream in ("permuted", "split"):
        for n_h in (100, 256) if not fast else (100,):
            tasks = build_scenario(stream, seed=0, n_tasks=p["n_tasks"],
                                   n_train=p["n_train"],
                                   n_test=p["n_test"])
            cfg = scenario_miru_config(tasks, n_h=n_h)
            for label, algo, device in SETUPS:
                t0 = time.time()
                res = run_compiled(
                    cfg, TrainerSpec(algo=algo,
                                     epochs_per_task=p["epochs"],
                                     batch_size=32),
                    tasks, replay=ReplaySpec(capacity=512),
                    device=device)
                key = f"{stream}_nh{n_h}_{label}"
                out[key] = {"MA": res["MA"],
                            "acc_after_each": res["acc_after_each"],
                            "final_row": res["R"][-1].tolist(),
                            "metrics": res["metrics"]}
                emit(f"fig4/{key}", (time.time() - t0) * 1e6,
                     f"MA={res['MA']:.3f};"
                     f"F={res['metrics']['forgetting']:+.3f}")
    # Headline deltas.
    for stream in ("permuted", "split"):
        sw = out[f"{stream}_nh100_dfa"]["MA"]
        hw = out[f"{stream}_nh100_dfa_hw"]["MA"]
        adam = out[f"{stream}_nh100_adam"]["MA"]
        out[f"{stream}_gaps"] = {"hw_vs_dfa": sw - hw,
                                 "dfa_vs_adam": adam - sw}
        emit(f"fig4/{stream}_hw_gap", 0.0,
             f"hw_gap={sw - hw:+.3f};dfa_vs_adam={adam - sw:+.3f}")
    save_json("fig4_continual", out)
    return out


if __name__ == "__main__":
    run()
