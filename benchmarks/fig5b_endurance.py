"""Fig. 5b: memristor write CDF before/after K-WTA gradient
sparsification + projected lifespan (6.9 → 12.2 years @1 ms updates,
10⁹ endurance). The lifetime now comes from the metered write maps via
``repro.telemetry.lifetime`` (pulse-rate calibrated absolute years), with
the raw rate-scaling figures kept alongside."""
from __future__ import annotations

import time

import numpy as np

from repro.analog.endurance import lifespan_years
from repro.core.continual import ContinualConfig, run_continual
from repro.core.miru import MiRUConfig
from repro.data.synthetic import make_permuted_tasks
from repro.telemetry import project_lifetime

from benchmarks.common import emit, save_json


def run() -> dict:
    tasks = make_permuted_tasks(0, n_tasks=3, n_train=400, n_test=100)
    cfg = MiRUConfig(n_x=28, n_h=100, n_y=10)
    out = {}
    rates = {}
    for name, keep in (("dense", None), ("sparsified", 0.57)):
        t0 = time.time()
        tspec, rspec, backend = ContinualConfig(
            trainer="dfa", epochs_per_task=4, batch_size=32,
            replay_capacity=256, kwta_keep_frac=keep,
            track_endurance=True).specs()
        res = run_continual(cfg, tspec, tasks, replay=rspec, device=backend)
        tracker = res["endurance"]
        rate = tracker.mean_writes() / max(tracker.updates_applied, 1)
        xs, cdf = tracker.write_cdf(64)
        proj = project_lifetime(tracker)
        rates[name] = rate
        out[name] = {
            "mean_writes_per_update": rate,
            "updates": tracker.updates_applied,
            "cdf_x": xs.tolist(), "cdf_y": cdf.tolist(),
            "lifespan_years@1ms": lifespan_years(rate),
            "projected_years": proj.years_mean,
            "projected_years_hot_tail": proj.years_hot_tail,
            "MA": res["MA"],
        }
        emit(f"fig5b/{name}", (time.time() - t0) * 1e6,
             f"write_rate={rate:.3f};"
             f"projected_years={proj.years_mean:.1f}")
    reduction = 1.0 - rates["sparsified"] / rates["dense"]
    gain = out["sparsified"]["projected_years"] \
        / out["dense"]["projected_years"]
    out["write_reduction"] = reduction
    out["lifespan_gain"] = gain
    out["paper"] = {"write_reduction": 0.47, "dense_years": 6.9,
                    "sparse_years": 12.2, "gain": 12.2 / 6.9}
    emit("fig5b/summary", 0.0,
         f"write_reduction={reduction*100:.1f}%;lifespan_gain={gain:.2f}x;"
         f"years={out['dense']['projected_years']:.1f}->"
         f"{out['sparsified']['projected_years']:.1f}"
         f"(paper 6.9->12.2)")
    save_json("fig5b_endurance", out)
    return out


if __name__ == "__main__":
    run()
