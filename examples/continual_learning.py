"""End-to-end driver (the paper's workload): domain-incremental continual
learning on the M2RU accelerator model — several hundred training steps
through a sequence of tasks with reservoir replay, DFA-through-time,
K-WTA-sparsified noisy crossbar writes, WBS-quantized inference, and
endurance tracking with a lifespan projection.

    PYTHONPATH=src python examples/continual_learning.py [--trainer dfa_hw]
"""
import argparse

from repro.analog.costmodel import M2RUCostModel
from repro.core.continual import ContinualConfig, run_continual
from repro.core.miru import MiRUConfig
from repro.data.synthetic import make_permuted_tasks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trainer", default="dfa_hw",
                    choices=["adam", "dfa", "dfa_hw"])
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--hidden", type=int, default=100)
    args = ap.parse_args()

    tasks = make_permuted_tasks(seed=0, n_tasks=args.tasks, n_train=600,
                                n_test=200)
    cfg = MiRUConfig(n_x=28, n_h=args.hidden, n_y=10)
    ccfg = ContinualConfig(trainer=args.trainer,
                           epochs_per_task=args.epochs, batch_size=32,
                           replay_capacity=512,
                           track_endurance=args.trainer != "adam")
    n_steps = args.tasks * args.epochs * (600 // 32)
    print(f"trainer={args.trainer}  tasks={args.tasks}  "
          f"~{n_steps} training steps")
    res = run_continual(cfg, ccfg, tasks)

    print("\naccuracy after each task (mean over seen tasks):")
    for t, a in enumerate(res["acc_after_each"]):
        print(f"  task {t}: {a:.3f}")
    print(f"final mean accuracy (eq. 20): {res['MA']:.3f}")
    print(f"final per-task accuracies:   "
          f"{[round(float(a), 3) for a in res['R'][-1]]}")

    if "endurance" in res:
        tracker = res["endurance"]
        rate = tracker.mean_writes() / max(tracker.updates_applied, 1)
        m = M2RUCostModel(n_h=args.hidden)
        print(f"\nmemristor write rate: {rate:.3f} writes/device/update")
        gain = 1.0 / max(rate, 1e-9)
        print(f"lifespan gain vs dense writes: {gain:.2f}× "
              f"(paper's ζ gain: 12.2/6.9 = 1.77×; absolute years depend "
              f"on workload write density)")
        print(f"accelerator: {m.gops():.1f} GOPS @ "
              f"{m.power_w()*1e3:.2f} mW → {m.gops_per_watt():.0f} GOPS/W")


if __name__ == "__main__":
    main()
