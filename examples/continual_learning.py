"""End-to-end driver (the paper's workload): domain-incremental continual
learning on a pluggable device substrate — several hundred training steps
through a sequence of tasks with reservoir replay, DFA-through-time,
K-WTA-sparsified noisy crossbar writes, WBS-quantized inference, and
device telemetry: power, GOPS/W and the lifetime projection are metered
from the run's own backend activity (repro.telemetry).

The algorithm (--algo adam|dfa) and the substrate (--backend, any name in
the repro.backends registry) compose freely; the legacy combined trainer
strings (adam | dfa | dfa_hw) keep working via --trainer.

    PYTHONPATH=src python examples/continual_learning.py --algo dfa --backend analog_state
    PYTHONPATH=src python examples/continual_learning.py --trainer dfa_hw   # legacy
"""
import argparse

from repro.analog.costmodel import M2RUCostModel
from repro.backends import available_backends, get_backend
from repro.core.continual import (ContinualConfig, ReplaySpec, TrainerSpec,
                                  run_continual)
from repro.core.miru import MiRUConfig
from repro.data.synthetic import make_permuted_tasks
from repro.telemetry import format_report, telemetry_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trainer", default=None,
                    choices=["adam", "dfa", "dfa_hw"],
                    help="legacy combined trainer string (shim path)")
    ap.add_argument("--algo", default=None, choices=["adam", "dfa"],
                    help="learning rule (default: dfa)")
    ap.add_argument("--backend", default=None,
                    choices=list(available_backends()),
                    help="device substrate from the backend registry "
                         "(default: analog_state)")
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--hidden", type=int, default=100)
    ap.add_argument("--no-telemetry", action="store_true",
                    help="skip activity metering + the energy report")
    args = ap.parse_args()

    tasks = make_permuted_tasks(seed=0, n_tasks=args.tasks, n_train=600,
                                n_test=200)
    cfg = MiRUConfig(n_x=28, n_h=args.hidden, n_y=10)

    if args.trainer is not None:
        if args.algo is not None or args.backend is not None:
            ap.error("--trainer (legacy) conflicts with --algo/--backend; "
                     "pass one or the other")
        # Legacy path: the flat config maps onto the specs + registry.
        ccfg = ContinualConfig(trainer=args.trainer,
                               epochs_per_task=args.epochs, batch_size=32,
                               replay_capacity=512,
                               track_endurance=args.trainer != "adam")
        trainer, replay, backend = ccfg.specs()
    else:
        algo = args.algo or "dfa"
        name = args.backend or "analog_state"
        trainer = TrainerSpec(algo=algo, epochs_per_task=args.epochs,
                              batch_size=32)
        replay = ReplaySpec(capacity=512)
        backend = get_backend(
            name, spec_overrides=dict(track_endurance=algo != "adam"))

    if not args.no_telemetry:
        backend.telemetry.enable()
    n_steps = args.tasks * args.epochs * (600 // 32)
    print(f"algo={trainer.algo}  backend={backend.name}  "
          f"tasks={args.tasks}  ~{n_steps} training steps")
    res = run_continual(cfg, trainer, tasks, replay=replay, device=backend)

    print("\naccuracy after each task (mean over seen tasks):")
    for t, a in enumerate(res["acc_after_each"]):
        print(f"  task {t}: {a:.3f}")
    print(f"final mean accuracy (eq. 20): {res['MA']:.3f}")
    print(f"final per-task accuracies:   "
          f"{[round(float(a), 3) for a in res['R'][-1]]}")

    m = M2RUCostModel(n_h=args.hidden)
    if backend.telemetry.enabled:
        # Metered numbers from the run that just happened — power, GOPS/W
        # and lifetime derived from the backend's own activity counters.
        kind = "cmos" if backend.name == "cmos" else "analog"
        # Lifetime only makes sense for memristive substrates — SRAM
        # weight registers in the CMOS baseline have no endurance limit.
        tracker = res.get("endurance") if kind == "analog" else None
        rep = telemetry_report(backend.telemetry, model=m, kind=kind,
                               tracker=tracker)
        print("\ndevice telemetry (metered from this run):")
        print(format_report(rep))
    elif "endurance" in res:
        tracker = res["endurance"]
        rate = tracker.mean_writes() / max(tracker.updates_applied, 1)
        print(f"\nmemristor write rate: {rate:.3f} writes/device/update")
        gain = 1.0 / max(rate, 1e-9)
        print(f"lifespan gain vs dense writes: {gain:.2f}× "
              f"(paper's ζ gain: 12.2/6.9 = 1.77×; absolute years depend "
              f"on workload write density)")
        print(f"accelerator: {m.gops():.1f} GOPS @ "
              f"{m.power_w()*1e3:.2f} mW → {m.gops_per_watt():.0f} GOPS/W")


if __name__ == "__main__":
    main()
