"""End-to-end driver (the paper's workload): domain-incremental continual
learning on a pluggable device substrate — several hundred training steps
through a sequence of tasks with reservoir replay, DFA-through-time,
K-WTA-sparsified noisy crossbar writes, WBS-quantized inference, and
device telemetry: power, GOPS/W and the lifetime projection are metered
from the run's own backend activity (repro.telemetry).

The task stream (--scenario, any name in the repro.scenarios registry),
the algorithm (--algo adam|dfa) and the substrate (--backend, any name
in the repro.backends registry) compose freely. By default the whole
sequence runs compiled — one jit, scan-over-tasks
(repro.scenarios.sweep) — and reports forgetting/transfer metrics next
to accuracy; --loop uses the per-task Python loop instead (bit-identical
on the ideal backend). The legacy combined trainer strings
(adam | dfa | dfa_hw) keep working via --trainer.

The rehearsal layer is pluggable too: --replay-policy picks any
registered repro.replay policy (reservoir | ring | class_balanced |
task_stratified | loss_aware); without the flag, the scenario's
preferred policy applies (class_incremental rehearses class-balanced,
drift rides the FIFO ring) and reservoir remains the global default.

Observability (repro.obs, see docs/observability.md): --obs-cadence N
collects the in-scan metric streams into a RunLog (timeline rendered in
the telemetry report), --trace writes a Chrome/Perfetto trace.json with
schedule/compile/execute spans, --record appends a schema-versioned
run-record JSONL. One command produces all three:

    PYTHONPATH=src python examples/continual_learning.py \
        --backend analog_state --obs-cadence 10 \
        --trace trace.json --record run.jsonl

The real sequential streams (seq_mnist, seq_cifar10 — docs/data.md) and
the ragged keyword_fewshot stream run through the same compiled sweep:
the scenario's registered PadPolicy routes them through the masked
program, and --offline pins the checksum-verified download path to the
deterministic surrogate.

    PYTHONPATH=src python examples/continual_learning.py --algo dfa --backend analog_state
    PYTHONPATH=src python examples/continual_learning.py --scenario seq_mnist --offline
    PYTHONPATH=src python examples/continual_learning.py --scenario rotated --seeds 3
    PYTHONPATH=src python examples/continual_learning.py --scenario class_incremental --replay-policy loss_aware
    PYTHONPATH=src python examples/continual_learning.py --trainer dfa_hw   # legacy
"""
import argparse
import dataclasses

from repro.analog.costmodel import M2RUCostModel
from repro.backends import available_backends, get_backend
from repro.core.continual import (ContinualConfig, ReplaySpec, TrainerSpec,
                                  run_continual)
from repro.core.miru import MiRUConfig
from repro.replay import available_policies
from repro.scenarios import (available_scenarios, build_scenario,
                             get_scenario, run_compiled,
                             scenario_miru_config)
from repro.telemetry import format_report, telemetry_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trainer", default=None,
                    choices=["adam", "dfa", "dfa_hw"],
                    help="legacy combined trainer string (shim path)")
    ap.add_argument("--algo", default=None, choices=["adam", "dfa"],
                    help="learning rule (default: dfa)")
    ap.add_argument("--backend", default=None,
                    choices=list(available_backends()),
                    help="device substrate from the backend registry "
                         "(default: analog_state)")
    ap.add_argument("--scenario", default="permuted",
                    choices=list(available_scenarios()),
                    help="task stream from the scenario registry")
    ap.add_argument("--replay-policy", default=None,
                    choices=list(available_policies()),
                    help="replay policy from the repro.replay registry "
                         "(default: the scenario's preferred policy, "
                         "else reservoir)")
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--offline", action="store_true",
                    help="real-data scenarios (seq_mnist, seq_cifar10): "
                         "skip the download and use the deterministic "
                         "synthetic surrogate (same as REPRO_DATA_OFFLINE=1)")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--hidden", type=int, default=100)
    ap.add_argument("--seeds", type=int, default=1,
                    help="replicate over N seeds inside one vmapped "
                         "compiled run (metrics mean ± std)")
    ap.add_argument("--loop", action="store_true",
                    help="use the per-task Python loop instead of the "
                         "compiled scan-over-tasks sweep")
    ap.add_argument("--no-fused", action="store_true",
                    help="force the per-timestep device_vmm recurrence "
                         "instead of the fused one-kernel WBS×MiRU scan "
                         "(bit-identical; fused is the fast default on "
                         "substrates that support it)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="skip activity metering + the energy report")
    ap.add_argument("--obs-cadence", type=int, default=None, metavar="N",
                    help="collect the repro.obs metric streams, windowed "
                         "every N training steps (timeline in the report)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace.json "
                         "(schedule/compile/execute spans)")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="append a schema-versioned run-record to this "
                         "JSONL file")
    args = ap.parse_args()

    obs = tracer = None
    if args.obs_cadence is not None or args.trace or args.record:
        from repro.obs import ObsSpec, Tracer
        if args.trace:
            tracer = Tracer(process_name="continual_learning")
        obs = ObsSpec(cadence=args.obs_cadence or 1, tracer=tracer)

    scenario_kwargs = dict(n_tasks=args.tasks, n_train=600, n_test=200)
    if args.offline:
        # Only the downloading builders take the knob; the synthetic
        # streams are offline by construction.
        if args.scenario not in ("seq_mnist", "seq_cifar10"):
            ap.error("--offline only applies to the real-data scenarios "
                     "(seq_mnist, seq_cifar10)")
        scenario_kwargs["offline"] = True
    tasks = build_scenario(args.scenario, seed=0, **scenario_kwargs)
    cfg = scenario_miru_config(tasks, n_h=args.hidden)

    if args.trainer is not None:
        if args.algo is not None or args.backend is not None:
            ap.error("--trainer (legacy) conflicts with --algo/--backend; "
                     "pass one or the other")
        # Legacy path: the flat config maps onto the specs + registry.
        ccfg = ContinualConfig(trainer=args.trainer,
                               epochs_per_task=args.epochs, batch_size=32,
                               replay_capacity=512,
                               track_endurance=args.trainer != "adam",
                               fused_recurrence=not args.no_fused)
        trainer, replay, backend = ccfg.specs()
    else:
        algo = args.algo or "dfa"
        name = args.backend or "analog_state"
        trainer = TrainerSpec(algo=algo, epochs_per_task=args.epochs,
                              batch_size=32)
        replay = ReplaySpec(capacity=512)
        backend = get_backend(
            name, spec_overrides=dict(track_endurance=algo != "adam"))

    # Scenario protocols can pin trainer fields (streaming is single-pass).
    scenario = get_scenario(args.scenario)
    overrides = scenario.trainer_overrides
    if overrides or args.no_fused:
        if args.no_fused:
            overrides = dict(overrides, fused_recurrence=False)
        trainer = dataclasses.replace(trainer, **overrides)
    # Replay policy: the explicit flag wins; otherwise the scenario's
    # preferred policy (same resolution rule as trainer_overrides).
    if args.replay_policy is not None:
        replay = dataclasses.replace(replay, policy=args.replay_policy)
    replay = scenario.resolve_replay(replay)

    if not args.no_telemetry:
        backend.telemetry.enable()
    n_steps = args.tasks * trainer.epochs_per_task * (600 // 32)
    mode = "python loop" if args.loop else "compiled scan-over-tasks"
    print(f"scenario={args.scenario}  algo={trainer.algo}  "
          f"backend={backend.name}  replay={replay.resolved_policy}  "
          f"tasks={args.tasks}  ~{n_steps} training steps  [{mode}]")
    if args.loop:
        if args.seeds > 1:
            ap.error("--seeds replicates inside the compiled sweep; "
                     "drop --loop to use it")
        res = run_continual(cfg, trainer, tasks, replay=replay,
                            device=backend, obs=obs, pad=scenario.pad)
    else:
        seeds = list(range(args.seeds)) if args.seeds > 1 else None
        res = run_compiled(cfg, trainer, tasks, replay=replay,
                           device=backend, seeds=seeds, obs=obs,
                           uniform=scenario.uniform, pad=scenario.pad)

    print("\naccuracy after each task (mean over seen tasks):")
    for t, a in enumerate(res["acc_after_each"]):
        print(f"  task {t}: {a:.3f}")
    print(f"final mean accuracy (eq. 20): {res['MA']:.3f}")
    print(f"final per-task accuracies:   "
          f"{[round(float(a), 3) for a in res['R'][-1]]}")
    if "metrics" in res:
        m = res["metrics"]
        std = res.get("metrics_std", {})

        def fmt(k):
            s = f"{m[k]:+.3f}"
            return s + (f" ± {std[k]:.3f}" if k in std else "")

        line = (f"forgetting: {fmt('forgetting')}   "
                f"BWT: {fmt('backward_transfer')}")
        if "forward_transfer" in m:
            line += f"   FWT: {fmt('forward_transfer')}"
        print(line)

    m = M2RUCostModel(n_h=args.hidden)
    if backend.telemetry.enabled:
        # Metered numbers from the run that just happened — power, GOPS/W
        # and lifetime derived from the backend's own activity counters.
        kind = "cmos" if backend.name == "cmos" else "analog"
        # Lifetime only makes sense for memristive substrates — SRAM
        # weight registers in the CMOS baseline have no endurance limit.
        tracker = res.get("endurance") if kind == "analog" else None
        rep = telemetry_report(backend.telemetry, model=m, kind=kind,
                               tracker=tracker,
                               runlog=res.get("runlog"))
        print("\ndevice telemetry (metered from this run):")
        print(format_report(rep))
    elif "endurance" in res:
        tracker = res["endurance"]
        rate = tracker.mean_writes() / max(tracker.updates_applied, 1)
        print(f"\nmemristor write rate: {rate:.3f} writes/device/update")
        gain = 1.0 / max(rate, 1e-9)
        print(f"lifespan gain vs dense writes: {gain:.2f}× "
              f"(paper's ζ gain: 12.2/6.9 = 1.77×; absolute years depend "
              f"on workload write density)")
        print(f"accelerator: {m.gops():.1f} GOPS @ "
              f"{m.power_w()*1e3:.2f} mW → {m.gops_per_watt():.0f} GOPS/W")

    if "runlog" in res and not backend.telemetry.enabled:
        # Telemetry off but streams requested: render the timeline alone.
        from repro.telemetry import format_timeline
        from repro.obs import timeline
        print("\n" + format_timeline(timeline(res["runlog"])))

    if tracer is not None:
        if "compile_s" in res:
            print(f"\ncompile {res['compile_s']:.2f} s / execute "
                  f"{res['execute_s']:.3f} s (AOT-separated)")
        path = tracer.export_chrome(args.trace)
        print(f"trace written to {path}")
    if args.record:
        from repro.obs import JsonlSink, run_record
        metrics = {"MA": res["MA"], "wall_s": res.get("wall_s")}
        if "metrics" in res:
            metrics.update(res["metrics"])
        rec = run_record(
            "run", "continual", metrics,
            counters=(backend.telemetry.snapshot()
                      if backend.telemetry.enabled else None),
            timeline=(res["runlog"].as_dict(max_points=200)
                      if "runlog" in res else None),
            extra={"scenario": args.scenario, "backend": backend.name,
                   "algo": trainer.algo,
                   "replay_policy": replay.resolved_policy})
        path = JsonlSink(args.record).emit(rec)
        print(f"run record appended to {path}")


if __name__ == "__main__":
    main()
