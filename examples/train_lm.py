"""Train an LM with the full production trainer: deterministic sharded
data, AdamW(+optional ζ sparsification / top-k gradient compression),
checkpoint/restart, preemption handling, straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-4b --steps 2 \
        --full   # full config: a few steps only on CPU

The default runs a reduced config a few hundred steps and demonstrates a
mid-run restart from checkpoint.
"""
import argparse
import tempfile

import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.data.pipeline import ShardedBatcher
from repro.data.synthetic import lm_token_batch
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (few steps only on CPU)")
    ap.add_argument("--kwta", type=float, default=None,
                    help="ζ gradient sparsification keep-fraction")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full \
        else get_smoke_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("use a decoder-only arch for this example")

    def gen(rng: np.random.Generator, step: int):
        return lm_token_batch(rng, args.batch, args.seq, cfg.vocab)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainConfig(steps=args.steps, lr=3e-4, warmup_steps=20,
                           checkpoint_every=max(args.steps // 2, 1),
                           checkpoint_dir=ckpt_dir, log_every=20,
                           kwta_grad_keep=args.kwta)
        trainer = Trainer(cfg, tcfg, ShardedBatcher(gen, seed=0))
        print(f"arch={cfg.name}  params={trainer.n_params:,}")

        # Phase 1: train most of the way, checkpointing as we go.
        trainer.run(steps=args.steps // 2 + args.steps // 4)
        loss_before = trainer.history[-1]["loss"]
        trainer.save(async_=False)

        # Phase 2: simulate failure + restart — fresh trainer restores
        # params/optimizer/data state and continues bit-identically.
        restarted = Trainer(cfg, tcfg, ShardedBatcher(gen, seed=0))
        assert restarted.maybe_restore(), "checkpoint restore failed"
        print(f"restored at step {restarted.step} "
              f"(loss was {loss_before:.4f}); continuing")
        restarted.run(steps=args.steps - restarted.step)

        last = restarted.history[-1]["loss"]
        print(f"final loss {last:.4f}  "
              f"(start {trainer.history[0]['loss']:.4f})")
        stragglers = restarted.monitor.straggler_events
        print(f"straggler events: {len(stragglers)}")
        if args.steps >= 100:      # below that, warmup dominates
            assert last < trainer.history[0]["loss"], "loss must decrease"


if __name__ == "__main__":
    main()
