"""Population-scale continual learning: a sharded fleet of simulated
chips (repro.fleet) running the paper's workload — each device with its
own fabrication draw (per-chip crossbar parameters + per-cell G⁺/G⁻
programming) and its own data stream, trained inside one compiled
shard_map program, then folded into population distributions:
p50/p95/p99 power, GOPS/W, lifetime-years and forgetting, with the
worst chips called out.

    PYTHONPATH=src python examples/fleet_sim.py
    PYTHONPATH=src python examples/fleet_sim.py --devices 16 --profile harsh
    PYTHONPATH=src python examples/fleet_sim.py --emulate 8   # 8-way mesh on CPU

--emulate N sets --xla_force_host_platform_device_count before jax
loads, so the fleet axis actually shards N ways (results are
bit-identical across mesh shapes either way).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="fleet size (simulated chips)")
    ap.add_argument("--profile", default="mild",
                    choices=["none", "mild", "harsh"],
                    help="device-to-device heterogeneity profile")
    ap.add_argument("--backend", default="analog_state",
                    help="device substrate (heterogeneity needs "
                         "conductance-domain state: analog_state)")
    ap.add_argument("--scenario", default="permuted")
    ap.add_argument("--tasks", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--hidden", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--emulate", type=int, default=None, metavar="N",
                    help="emulate N host devices (CPU) so the fleet "
                         "axis shards N ways; must be set before jax "
                         "loads, so pass it rather than exporting "
                         "XLA_FLAGS by hand")
    args = ap.parse_args()

    if args.emulate is not None:
        if "jax" in sys.modules:
            ap.error("--emulate must take effect before jax is imported")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.emulate}"
        ).strip()

    import jax

    from repro.backends import get_backend
    from repro.core.continual import ReplaySpec, TrainerSpec
    from repro.fleet import FleetSpec, fleet_aggregate, run_fleet
    from repro.scenarios import build_scenario, scenario_miru_config
    from repro.telemetry.report import format_fleet

    tasks = build_scenario(args.scenario, seed=args.seed,
                           n_tasks=args.tasks, n_train=256, n_test=128)
    cfg = scenario_miru_config(tasks, n_h=args.hidden)
    trainer = TrainerSpec(algo="dfa", epochs_per_task=args.epochs)

    backend = get_backend(args.backend)
    backend.telemetry.enable()
    fleet = FleetSpec(n_devices=args.devices, het_profile=args.profile,
                      seed=args.seed)
    print(f"fleet: {fleet.n_devices} chips, profile={fleet.het_profile}, "
          f"backend={backend.name}, host devices={len(jax.devices())}")

    res = run_fleet(cfg, trainer, tasks, fleet,
                    replay=ReplaySpec(capacity=256), device=backend)
    print(f"ran {res['n_devices']} devices on a {res['n_shards']}-shard "
          f"mesh ({res['n_local']} local each) in {res['wall_s']:.1f}s — "
          f"{res['updates_per_device']} updates/chip")

    print("\nper-chip final accuracy / forgetting:")
    for i, (s, cell) in enumerate(zip(res["device_seeds"],
                                      res["per_device"])):
        m = cell["metrics"]
        print(f"  chip {i:2d} (seed {s:>10d}): "
              f"ACC={m['average_accuracy']:.3f}  "
              f"F={m['forgetting']:+.3f}")

    agg = fleet_aggregate(res)
    print("\nfleet aggregate (population distributions):")
    print(format_fleet(agg))


if __name__ == "__main__":
    main()
