"""Quickstart: train a MiRU classifier with DFA in ~30 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfa import dfa_grads, sgd_kwta_update
from repro.core.miru import (MiRUConfig, init_dfa_feedback,
                             init_miru_params, miru_forward)
from repro.data.synthetic import make_permuted_tasks
from repro.utils import accuracy


def main():
    task = make_permuted_tasks(seed=0, n_tasks=1, n_train=800,
                               n_test=300)[0]
    cfg = MiRUConfig(n_x=28, n_h=100, n_y=10, beta=0.8, lam=0.5)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    psi = init_dfa_feedback(jax.random.PRNGKey(1), cfg)

    @jax.jit
    def step(params, xb, yb):
        loss, grads = dfa_grads(params, psi, cfg, xb, yb)
        params, _ = sgd_kwta_update(params, grads, lr=0.2, keep_frac=0.57,
                                    hidden_lr_scale=0.3)
        return params, loss

    rng = np.random.default_rng(0)
    for it in range(400):
        idx = rng.integers(0, task.x_train.shape[0], 64)
        params, loss = step(params, jnp.asarray(task.x_train[idx]),
                            jnp.asarray(task.y_train[idx]))
        if it % 100 == 0:
            logits, _ = miru_forward(params, cfg,
                                     jnp.asarray(task.x_test))
            acc = accuracy(logits, jnp.asarray(task.y_test))
            print(f"step {it:4d}  loss {float(loss):.3f}  "
                  f"test acc {float(acc):.3f}")

    logits, _ = miru_forward(params, cfg, jnp.asarray(task.x_test))
    print(f"final test accuracy (DFA + K-WTA): "
          f"{float(accuracy(logits, jnp.asarray(task.y_test))):.3f}")


if __name__ == "__main__":
    main()
