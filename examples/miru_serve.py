"""Serve the paper's MiRU to many concurrent user streams.

    PYTHONPATH=src python examples/miru_serve.py --requests 24 --slots 4

Continuous batching of recurrent state: each user's conversation state
is one hidden vector in a device-resident slab; a burst of requests
from returning users churns the slab (LRU spill to host + bit-identical
reload) while the fused device step advances every active stream at
once. ``--meter`` reports serving power and a pJ/request histogram
from the live telemetry counters. See docs/serving.md.
"""
import argparse

import jax

from repro.core.miru import MiRUConfig, init_miru_params
from repro.serve import (RecurrentServeConfig, RecurrentServeEngine,
                         TrafficSpec, replay)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--users", type=int, default=10,
                    help="distinct users; fewer users than requests "
                         "means returning users resuming their state")
    ap.add_argument("--slots", type=int, default=4,
                    help="device slab slots (< users forces LRU spill)")
    ap.add_argument("--chunk", type=int, default=7)
    ap.add_argument("--device", default="wbs")
    ap.add_argument("--meter", action="store_true")
    args = ap.parse_args()

    # Paper geometry: 28 features x 100 hidden x 10 classes.
    cfg = MiRUConfig(n_x=28, n_h=100, n_y=10)
    params = init_miru_params(jax.random.PRNGKey(0), cfg)
    eng = RecurrentServeEngine(
        cfg,
        RecurrentServeConfig(batch_slots=args.slots, chunk=args.chunk,
                             device=args.device, meter=args.meter,
                             fresh_meter=args.meter),
        params)

    spec = TrafficSpec(n_requests=args.requests, n_users=args.users,
                       frames_min=8, frames_max=28, n_x=cfg.n_x, seed=0)
    reqs = [(a, eng.submit(frames, uid=a.uid)) for a, frames in replay(spec)]
    eng.run_until_drained()

    for a, r in reqs[:6]:
        print(f"user {a.uid:>3} rid {a.rid:>2}: {r.emitted} frames -> "
              f"class {int(r.predictions[-1])}")
    if len(reqs) > 6:
        print(f"... and {len(reqs) - 6} more")

    stats = eng.request_stats()
    slab = stats["slab"]
    print(f"\nserved {stats['requests']} requests "
          f"({stats['frames_served']} frames) for {args.users} users on "
          f"{args.slots} slots in {stats['steps_run']} engine steps")
    print(f"slab: {slab['evictions']} evictions, {slab['reloads']} "
          f"bit-identical reloads, {slab['spilled']} streams spilled")
    lat = stats["latency_ms"]
    print(f"latency p50 {lat['p50']:.2f} ms  p99 {lat['p99']:.2f} ms; "
          f"{stats['sequences_per_s']:.0f} sequences/s  "
          f"{stats['frames_per_s']:.0f} frames/s")
    if "energy" in stats:
        e = stats["energy"]
        pj = e["pj_per_request"]
        print(f"energy: {e['power_mw']:.1f} mW serving power "
              f"({e['gops_per_w']:.1f} GOPS/W); "
              f"pJ/request p50 {pj['p50']:.3g}  p99 {pj['p99']:.3g}")


if __name__ == "__main__":
    main()
