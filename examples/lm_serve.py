"""Serve a small LM with batched requests through the slot engine.

    PYTHONPATH=src python examples/lm_serve.py --arch qwen2-0.5b
(uses the arch's reduced smoke config so it runs on CPU in seconds)

``--device <backend>`` runs the quantized substrate metered and reports
pJ/request next to the latency percentiles; ``--trace out.json`` writes
a Chrome trace of the serve loop (chrome://tracing / Perfetto).
"""
import argparse

import jax

from repro.configs import get_smoke_config, list_archs
from repro.models import lm
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--device", default=None,
                    help="quantized substrate registry name (e.g. wbs); "
                         "enables metering and pJ/request")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace.json of the serve loop")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving needs an encoder pass; "
                         "use a decoder-only arch for this example")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(process_name="lm_serve")
    scfg = ServeConfig(batch_slots=4, max_len=64, eos_token=-1,
                       device=args.device, meter=args.device is not None,
                       tracer=tracer)
    engine = ServeEngine(cfg, scfg, params)

    reqs = []
    for i in range(args.requests):
        prompt = [(7 * i + j) % cfg.vocab for j in range(1, 5 + i % 3)]
        reqs.append((prompt, engine.submit(prompt, max_new=8)))

    engine.run_until_drained()
    for prompt, req in reqs:
        assert req.done and len(req.tokens) == 8
        print(f"prompt={prompt} -> generated={req.tokens}")
    print(f"served {len(reqs)} requests in {engine.steps_run} "
          f"engine steps with 4 slots")

    # Metered runs report energy through the transformer-shape
    # DenseCostModel built from this arch's quantized projections
    # (request_stats' default when metering an LM engine).
    stats = engine.request_stats()
    lat = stats["latency_ms"]
    print(f"latency    p50 {lat['p50']:.2f} ms  p99 {lat['p99']:.2f} ms "
          f"(mean {lat['mean']:.2f})")
    qw, dec = stats["queue_wait_ms"], stats["decode_ms"]
    print(f"           queue-wait p50 {qw['p50']:.2f} ms  "
          f"decode p50 {dec['p50']:.2f} ms")
    print(f"throughput {stats['sequences_per_s']:.2f} sequences/s  "
          f"{stats['tokens_per_s']:.1f} tokens/s")
    if "energy" in stats:
        e = stats["energy"]
        pj = e["pj_per_request"]
        print(f"energy     {e['total_j']*1e6:.2f} µJ metered at "
              f"{e['power_mw']:.1f} mW ({e['gops_per_w']:.1f} GOPS/W, "
              f"{e['pj_per_op']:.1f} pJ/op); "
              f"pJ/request p50 {pj['p50']:.3g}  p99 {pj['p99']:.3g}")
    if tracer is not None:
        path = tracer.export_chrome(args.trace)
        print(f"trace written to {path}")


if __name__ == "__main__":
    main()
