"""Serve a small LM with batched requests through the slot engine.

    PYTHONPATH=src python examples/lm_serve.py --arch qwen2-0.5b
(uses the arch's reduced smoke config so it runs on CPU in seconds)
"""
import argparse

import jax

from repro.configs import get_smoke_config, list_archs
from repro.models import lm
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving needs an encoder pass; "
                         "use a decoder-only arch for this example")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, ServeConfig(batch_slots=4, max_len=64,
                                          eos_token=-1), params)

    reqs = []
    for i in range(args.requests):
        prompt = [(7 * i + j) % cfg.vocab for j in range(1, 5 + i % 3)]
        reqs.append((prompt, engine.submit(prompt, max_new=8)))

    engine.run_until_drained()
    for prompt, req in reqs:
        assert req.done and len(req.tokens) == 8
        print(f"prompt={prompt} -> generated={req.tokens}")
    print(f"served {len(reqs)} requests in {engine.steps_run} "
          f"engine steps with 4 slots")


if __name__ == "__main__":
    main()
